"""Packed-weight serving: params as QSQ bit-planes + scales.

Converts a model's param tree (and its descriptor tree) into the
:class:`~repro.quant.store.PackedWeight` form consumed by
``models.layers``: each large weight whose contraction axis is a known
logical axis ("embed" / "mlp" / "heads_inner") becomes bit-planes
``(.., K/32, 3, ..)`` + scales ``(.., K/G, ..)`` behind the uniform
WeightStore API.

Weights that stay dense: embeddings (gathered, not matmul'd), routers
(tiny + fp32-sensitive), attention output projections (contraction spans
heads x head_dim — would need a reshape view), norms/biases, conv kernels.

This is the dry-run/serving realization of the paper's "model crosses the
channel in 3-bit form and is decoded by shift/scale on chip": the serve_step
*arguments* carry ~3.2-5 bits per packed weight instead of 16, which is the
HBM-residency and weight-streaming win measured by
``benchmarks/bench_serve.py`` and the §Perf dry-run cells.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec
from repro.core.qsq import QSQConfig, quantize
from repro.models.base import ParamDesc, _is_desc
from repro.quant.store import (  # noqa: F401 — axes/paths re-exported
    CONTRACT_AXES,
    EXCLUDE_PATHS,
    PackedWeight,
    contract_idx,
    kernel_eligible,
)


def _fit_group(k: int, group_size: int) -> int:
    g = min(group_size, k)
    while k % g:
        g //= 2
    return max(g, 1)


def _should_pack(path: str, d: ParamDesc, min_numel: int) -> bool:
    if int(np.prod(d.shape)) < min_numel:
        return False
    return kernel_eligible(path, d)


def packed_param_descs(descs, group_size: int = 64, min_numel: int = 65536):
    """Descriptor tree for the packed form (dry-run abstract inputs).

    Packed leaves become PackedWeight nodes whose children are ParamDesc, so
    ``abstract_params`` / ``partition_specs`` descend into them and the
    jitted serve step takes PackedWeight arguments directly."""

    def leaf(path, d: ParamDesc):
        p = jax.tree_util.keystr(path)
        if not _should_pack(p, d, min_numel):
            return d
        idx = contract_idx(d)
        k = d.shape[idx]
        g = _fit_group(k, group_size)
        prefix_s, rest_s = d.shape[:idx], d.shape[idx + 1:]
        prefix_a, rest_a = d.axes[:idx], d.axes[idx + 1:]
        # the packed-words dim inherits the contraction axis' sharding
        # (FSDP over dp) — otherwise packed weights end up LESS sharded
        # than dense ones and per-device argument bytes grow 3x.
        cname = d.axes[idx]
        return PackedWeight(
            planes=ParamDesc(prefix_s + (k // codec.PLANE_GROUP, 3) + rest_s,
                             prefix_a + (cname, None) + rest_a,
                             dtype=jnp.int32, init="zeros"),
            scales=ParamDesc(prefix_s + (k // g,) + rest_s,
                             prefix_a + (cname,) + rest_a,
                             dtype=jnp.float32, init="zeros"),
            group_size=g, phi=4, rest_ndim=len(rest_s),
        )

    return jax.tree_util.tree_map_with_path(leaf, descs, is_leaf=_is_desc)


def pack_params(params, descs, group_size: int = 64, min_numel: int = 65536,
                phi: int = 4, refit_alpha: bool = True):
    """Real-array packing (serving engine load path) -> PackedWeight leaves."""

    def leaf(path, w, d: ParamDesc):
        p = jax.tree_util.keystr(path)
        if not _should_pack(p, d, min_numel):
            return w
        idx = contract_idx(d)
        k = d.shape[idx]
        g = _fit_group(k, group_size)
        cfg = QSQConfig(phi=phi, group_size=g, refit_alpha=refit_alpha)

        def enc(w2):  # w2: (K, ...rest)
            q = quantize(w2, cfg)
            return codec.pack_bitplane(q.codes()), q.scales

        fn = enc
        for _ in range(idx):  # vmap over stacked layer axes
            fn = jax.vmap(fn)
        planes, scales = fn(w)
        return PackedWeight(planes=planes, scales=scales, group_size=g,
                            phi=phi, rest_ndim=len(d.shape) - idx - 1)

    return jax.tree_util.tree_map_with_path(leaf, params, descs)


def packed_bits_report(descs, group_size: int = 64, min_numel: int = 65536) -> dict:
    """Bits accounting for the packed form vs dense bf16."""
    dense_bits = 0
    packed_bits = 0
    n_packed = 0
    flat = jax.tree_util.tree_flatten_with_path(descs, is_leaf=_is_desc)[0]
    for path, d in flat:
        numel = int(np.prod(d.shape))
        bits = 8 * numel * jnp.dtype(d.dtype).itemsize
        dense_bits += bits
        p = jax.tree_util.keystr(path)
        if _should_pack(p, d, min_numel):
            idx = contract_idx(d)
            k = d.shape[idx]
            g = _fit_group(k, group_size)
            packed_bits += 3 * numel + 32 * (numel // g)
            n_packed += 1
        else:
            packed_bits += bits
    return {
        "dense_bits": dense_bits,
        "packed_bits": packed_bits,
        "savings": 1 - packed_bits / max(dense_bits, 1),
        "n_packed_leaves": n_packed,
    }

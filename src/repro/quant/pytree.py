"""QSQ over parameter pytrees — legacy API over :mod:`repro.quant.store`.

This is the "encode the model before the channel, decode at the edge" layer
of the paper, generalized: any JAX param pytree can be converted to a
:class:`QuantizedParams` store (3-bit codes + scalars for quantized leaves,
untouched leaves kept as-is), shipped (checkpoint / DCN / broadcast), and
decoded back — or served *packed* through the Pallas fused dequant-matmul.

The leaf representations and the wire codec live in
:mod:`repro.quant.store` (the unified ``WeightStore``); this module keeps
the established pytree-level entry points, now producing
:class:`~repro.quant.store.QSQWeight` leaves (a ``QSQTensor`` subclass, so
existing isinstance checks keep working).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax

from repro.core.policy import QuantPolicy
from repro.core.qsq import QSQTensor
from repro.quant import store as _store


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedParams:
    """A param pytree where selected leaves are QSQTensor, others raw arrays."""

    tree: Any  # pytree with QSQWeight/QSQTensor and jax.Array leaves

    def tree_flatten(self):
        return (self.tree,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(tree=children[0])

    def dequantize(self, like=None):
        return dequantize_pytree(self, like)


def quantize_pytree(params, policy: QuantPolicy, descs=None) -> QuantizedParams:
    """Quantize every leaf the policy selects; keep the rest untouched.

    With ``descs`` (ParamDesc tree), matmul weights are grouped along their
    contraction axis (serving-kernel layout); without, grouping runs along
    axis 0, and 4-D conv weights use the channel-major view (Fig. 5).
    """
    return QuantizedParams(tree=_store.quantize_tree(params, policy, descs))


def dequantize_pytree(qp: QuantizedParams, like=None):
    """Decode every quantized leaf back to a dense array.

    ``like`` (optional pytree of arrays or ShapeDtypeStructs) supplies target
    dtypes; defaults to f32 for quantized leaves.
    """
    return _store.dense_tree(qp.tree, like)


def pytree_bits_report(params, qp: QuantizedParams) -> dict:
    """Eq. 11/12 accounting over a whole model (drives Fig. 9 at LLM scale)."""
    full_bits = 0
    for leaf in jax.tree_util.tree_leaves(params):
        full_bits += 8 * leaf.size * leaf.dtype.itemsize
    rep = _store.tree_bits_report(qp.tree)
    return {
        "full_bits": full_bits,
        "quantized_bits": rep["bits"],
        "memory_savings": 1.0 - rep["bits"] / max(full_bits, 1),
        "n_quantized_leaves": rep["n_store_leaves"],
        "n_leaves": rep["n_leaves"],
    }


def pack_pytree_wire(qp: QuantizedParams):
    """QuantizedParams -> (pytree of wire dicts / raw arrays)."""
    return _store.tree_to_wire(qp.tree)


def unpack_pytree_wire(wire) -> QuantizedParams:
    """Inverse of :func:`pack_pytree_wire` (lossless)."""
    return QuantizedParams(tree=_store.tree_from_wire(wire))

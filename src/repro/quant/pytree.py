"""QSQ over parameter pytrees.

This is the "encode the model before the channel, decode at the edge" layer
of the paper, generalized: any JAX param pytree can be converted to a
:class:`QuantizedParams` store (3-bit codes + scalars for quantized leaves,
untouched leaves kept as-is), shipped (checkpoint / DCN / broadcast), and
decoded back — or fed *packed* into the Pallas fused dequant-matmul.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec
from repro.core.policy import QuantPolicy, path_str
from repro.core.qsq import QSQTensor, dequantize, quantize


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedParams:
    """A param pytree where selected leaves are QSQTensor, others raw arrays."""

    tree: Any  # pytree with QSQTensor and jax.Array leaves

    def tree_flatten(self):
        return (self.tree,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(tree=children[0])

    def dequantize(self, like=None):
        return dequantize_pytree(self, like)


def _conv_view(leaf):
    """(kh, kw, cin, cout) -> channel-major view (cin, kh*kw*cout).

    The paper's Fig. 5 vectors run across channels of the convolution
    filters; QSQ groups along the leading axis, so put cin first."""
    w = jnp.moveaxis(leaf, 2, 0)
    return w.reshape(w.shape[0], -1)


def _conv_unview(levels_like, conv_shape):
    kh, kw, cin, cout = conv_shape
    return jnp.moveaxis(levels_like.reshape(cin, kh, kw, cout), 0, 2)


def quantize_pytree(params, policy: QuantPolicy) -> QuantizedParams:
    """Quantize every leaf the policy selects; keep the rest untouched.

    4-D conv weights are quantized in the channel-major view (Fig. 5)."""

    def _leaf(path, leaf):
        view = _conv_view(leaf) if leaf.ndim == 4 else leaf
        cfg = policy.config_for(path_str(path), view.shape)
        if cfg is None:
            return leaf
        q = quantize(view, cfg)
        if leaf.ndim == 4:
            q = QSQTensor(levels=q.levels, scales=q.scales,
                          group_size=q.group_size, phi=q.phi,
                          conv_shape=tuple(leaf.shape))
        return q

    tree = jax.tree_util.tree_map_with_path(_leaf, params)
    return QuantizedParams(tree=tree)


def dequantize_pytree(qp: QuantizedParams, like=None):
    """Decode every QSQTensor leaf back to a dense array.

    ``like`` (optional pytree of arrays or ShapeDtypeStructs) supplies target
    dtypes; defaults to f32 for quantized leaves.
    """
    def _leaf(leaf, ref=None):
        if isinstance(leaf, QSQTensor):
            dtype = ref.dtype if ref is not None else jnp.float32
            w = dequantize(leaf, dtype=dtype)
            if leaf.conv_shape is not None:
                w = _conv_unview(w, leaf.conv_shape)
            return w
        return leaf

    if like is None:
        return jax.tree_util.tree_map(
            _leaf, qp.tree, is_leaf=lambda x: isinstance(x, QSQTensor)
        )
    return jax.tree_util.tree_map(
        _leaf, qp.tree, like, is_leaf=lambda x: isinstance(x, QSQTensor)
    )


def pytree_bits_report(params, qp: QuantizedParams) -> dict:
    """Eq. 11/12 accounting over a whole model (drives Fig. 9 at LLM scale)."""
    full_bits = 0
    q_bits = 0
    n_quantized = 0
    n_total = 0
    for leaf in jax.tree_util.tree_leaves(params):
        full_bits += 8 * leaf.size * leaf.dtype.itemsize
    for leaf in jax.tree_util.tree_leaves(
        qp.tree, is_leaf=lambda x: isinstance(x, QSQTensor)
    ):
        n_total += 1
        if isinstance(leaf, QSQTensor):
            q_bits += leaf.nbits()
            n_quantized += 1
        else:
            q_bits += 8 * leaf.size * leaf.dtype.itemsize
    return {
        "full_bits": full_bits,
        "quantized_bits": q_bits,
        "memory_savings": 1.0 - q_bits / max(full_bits, 1),
        "n_quantized_leaves": n_quantized,
        "n_leaves": n_total,
    }


# --------------------------------------------------------------------------
# Wire form: every QSQTensor leaf -> {packed int32 words, scales, meta}.
# This is what the checkpoint writer stores and what crosses DCN in the
# gradient-compression path.
# --------------------------------------------------------------------------
def pack_pytree_wire(qp: QuantizedParams):
    """QuantizedParams -> (pytree of wire dicts / raw arrays)."""

    def _leaf(leaf):
        if not isinstance(leaf, QSQTensor):
            return leaf
        codes = leaf.codes().reshape(-1)
        return {
            "__qsq__": True,
            "packed": codec.pack_dense(codes, bits=3),
            "scales": leaf.scales,
            "shape": tuple(leaf.levels.shape),
            "group_size": leaf.group_size,
            "phi": leaf.phi,
            "conv_shape": tuple(leaf.conv_shape) if leaf.conv_shape else (),
        }

    return jax.tree_util.tree_map(
        _leaf, qp.tree, is_leaf=lambda x: isinstance(x, QSQTensor)
    )


def unpack_pytree_wire(wire) -> QuantizedParams:
    """Inverse of :func:`pack_pytree_wire`."""

    def _is_wire(x):
        return isinstance(x, dict) and x.get("__qsq__") is True

    def _leaf(leaf):
        if not _is_wire(leaf):
            return leaf
        n = int(np.prod(leaf["shape"]))
        codes = codec.unpack_dense(leaf["packed"], n).reshape(leaf["shape"])
        from repro.core.qsq import codes_to_levels

        return QSQTensor(
            levels=codes_to_levels(codes),
            scales=leaf["scales"],
            group_size=leaf["group_size"],
            phi=leaf["phi"],
            conv_shape=(tuple(int(x) for x in leaf["conv_shape"])
                        if len(leaf.get("conv_shape", ())) else None),
        )

    return QuantizedParams(
        tree=jax.tree_util.tree_map(_leaf, wire, is_leaf=_is_wire)
    )

"""Unified WeightStore: one leaf API over the three QSQ weight forms.

A model parameter can live in three interchangeable representations:

* **dense**  — a plain array (``DenseWeight`` or a raw ``jax.Array``),
* **qsq**    — signed QSQ levels + per-group scalars (``QSQWeight``, the
  transport/checkpoint form: human-readable int8 levels),
* **packed** — 3-bit bit-planes + per-group scalars (``PackedWeight``, the
  HBM/serving form the Pallas fused dequant-matmul consumes directly).

Every leaf exposes the same surface — ``as_dense()``, ``matmul(x)``,
``nbits()`` — and is a registered pytree node, so whole param trees mix
representations freely, flow through ``jax.lax.scan`` (stacked layer axes
are sliced off the array children; the aux metadata is stack-invariant),
and jit/pjit like any array tree.

Grouping geometry: ``rest_ndim`` counts the trailing output dims after the
grouped (contraction) axis.  The number of leading stack axes is derived
from the arrays at use time (``ndim - 1 - rest_ndim``), so a leaf sliced by
a layer scan decodes itself correctly without metadata rewrites.

Tree-level helpers quantize a param pytree under a :class:`QuantPolicy`
(grouping along the true contraction axis when descriptors are supplied),
convert to/from the 3-bit wire format, and build serving trees that keep
kernel-eligible weights packed end-to-end.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codec
from repro.core.policy import QuantPolicy, path_str
from repro.core.qsq import (
    LEVEL_TABLE,
    SM_LEVEL_TABLE,
    QSQTensor,
    _quantize_impl,
    codes_to_levels,
    levels_to_codes,
    levels_to_smcodes,
    quantize,
    smcodes_to_levels,
)

# Logical axes a 2-D-view matmul contracts over, and path fragments that
# must never be served packed (gathered embeddings, routers, convs, norms,
# SSM decay params; attention wo contracts over heads x head_dim jointly and
# is excluded by the stack-prefix rule below).
CONTRACT_AXES = ("embed", "mlp", "heads_inner")
STACK_AXES = ("layers", None)
EXCLUDE_PATHS = ("tok", "router", "conv", "norm", "a_log", "dt_bias")


def _is_desc(x) -> bool:
    # duck-typed ParamDesc check (avoids importing repro.models here, which
    # would create an import cycle models.layers -> quant.store -> models)
    return hasattr(x, "axes") and hasattr(x, "shape") and hasattr(x, "dtype")


def contract_idx(desc) -> int | None:
    """Index of the first contraction axis in a ParamDesc, else None."""
    for i, name in enumerate(desc.axes):
        if name in CONTRACT_AXES:
            return i
    return None


def kernel_eligible(path: str, desc) -> bool:
    """True if this param can be served as bit-planes through qsq_matmul:
    the contraction axis is leading (after scan-stack axes only) and its
    length is a multiple of the 32-code plane word."""
    if any(e in path for e in EXCLUDE_PATHS):
        return False
    idx = contract_idx(desc)
    if idx is None:
        return False
    if any(a not in STACK_AXES for a in desc.axes[:idx]):
        return False
    return desc.shape[idx] % codec.PLANE_GROUP == 0


def _conv_view(leaf):
    """(kh, kw, cin, cout) -> channel-major view (cin, kh*kw*cout) (Fig. 5)."""
    w = jnp.moveaxis(leaf, 2, 0)
    return w.reshape(w.shape[0], -1)


def _conv_unview(levels_like, conv_shape):
    kh, kw, cin, cout = conv_shape
    return jnp.moveaxis(levels_like.reshape(cin, kh, kw, cout), 0, 2)


# --------------------------------------------------------------------------
# LSB plane truncation — the progressive-wire analogue of the paper's CSD
# LSB truncation: a lower quality tier is realized from an already-quantized
# artifact by zeroing the least-significant code bit-planes, never by
# re-quantizing.
# --------------------------------------------------------------------------
def _trunc_code_mask(drop: int) -> int:
    """3-bit code mask with the ``drop`` least-significant planes zeroed."""
    if not 0 <= drop < 3:
        raise ValueError(f"drop must be 0, 1 or 2; got {drop}")
    return (~((1 << drop) - 1)) & 0x7


def plane_mask_for_drop(drop: int) -> int:
    """Public alias of the tier code mask: ``drop`` LSB planes -> 3-bit mask.

    These are the per-row mask values :meth:`PackedWeight.matmul` accepts
    (0b111 / 0b110 / 0b100 for drop 0 / 1 / 2 — ``kernels.ref.MASK_VARIANTS``).
    """
    return _trunc_code_mask(drop)


def max_level_delta(drop: int) -> int:
    """Worst-case |level change| from dropping ``drop`` LSB code planes.

    The per-weight reconstruction error of a truncated tier is bounded by
    ``max_level_delta(drop) * alpha`` for each group's scalar alpha (0 for
    drop=0, 2 for drop=1, 4 for drop=2), for either code format.

    Under the sign-magnitude recode (wire v2, the packed serving format)
    the bit-2 sign plane survives every mask, so truncation degrades + and
    - levels identically: drop=1 maps +-1 -> 0 and +-4 -> +-2.  The legacy
    Table II offset layout (negatives are offset codes) truncates
    asymmetrically (+4 -> +2 but -4 exact at drop=1); the bound below is
    the max over both formats' valid codes, so it holds for legacy
    artifacts too.
    """
    mask = _trunc_code_mask(drop)
    sm_valid = (0, 1, 2, 3, 5, 6, 7)  # 4 (-0) unused on valid streams
    return int(max(
        max(abs(int(LEVEL_TABLE[c]) - int(LEVEL_TABLE[c & mask]))
            for c in range(7)),  # 7 itself is unused on valid streams
        max(abs(int(SM_LEVEL_TABLE[c]) - int(SM_LEVEL_TABLE[c & mask]))
            for c in sm_valid),
    ))


# --------------------------------------------------------------------------
# Leaf representations
# --------------------------------------------------------------------------
class WeightStore:
    """Uniform API over the dense / qsq / packed leaf representations."""

    kind: str = "?"

    def as_dense(self, dtype=jnp.float32) -> jax.Array:
        raise NotImplementedError

    def matmul(self, x: jax.Array) -> jax.Array:
        """x (..., K) contracted with this weight (K, *rest) -> (..., *rest)."""
        raise NotImplementedError

    def nbits(self) -> int:
        """Total stored bits of this representation."""
        raise NotImplementedError


def is_store(x) -> bool:
    return isinstance(x, WeightStore)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class DenseWeight(WeightStore):
    """A dense array behind the WeightStore API."""

    value: jax.Array
    kind = "dense"

    def tree_flatten(self):
        return (self.value,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(value=children[0])

    @property
    def shape(self):
        return self.value.shape

    def as_dense(self, dtype=jnp.float32):
        return self.value.astype(dtype)

    def matmul(self, x):
        return jnp.tensordot(x, self.value.astype(x.dtype), axes=1)

    def nbits(self) -> int:
        return int(8 * self.value.size * jnp.dtype(self.value.dtype).itemsize)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QSQWeight(QSQTensor, WeightStore):
    """QSQ levels + scales, grouping axis anywhere (not just axis 0).

    Extends :class:`QSQTensor` (so legacy isinstance checks keep working)
    with ``rest_ndim``: the number of trailing dims after the grouped axis.
    ``None`` means legacy axis-0 grouping (``levels.ndim - 1``).  Leading
    stack axes (scan-stacked layers) are whatever remains; they are derived
    from the array rank at call time, which makes scan slicing transparent.
    """

    rest_ndim: int | None = None
    kind = "qsq"

    def tree_flatten(self):
        return (self.levels, self.scales), (
            self.group_size, self.phi, self.conv_shape, self.rest_ndim,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        levels, scales = children
        return cls(levels=levels, scales=scales, group_size=aux[0],
                   phi=aux[1], conv_shape=aux[2], rest_ndim=aux[3])

    @classmethod
    def from_tensor(cls, q: QSQTensor, rest_ndim: int | None = None):
        return cls(levels=q.levels, scales=q.scales, group_size=q.group_size,
                   phi=q.phi, conv_shape=q.conv_shape, rest_ndim=rest_ndim)

    def _rest(self) -> int:
        return self.rest_ndim if self.rest_ndim is not None else self.levels.ndim - 1

    def _stack(self) -> int:
        return self.levels.ndim - 1 - self._rest()

    def as_dense(self, dtype=jnp.float32):
        def dq(lev, sc):
            ng = sc.shape[0]
            g = lev.shape[0] // max(ng, 1)
            out = lev.astype(jnp.float32).reshape(ng, g, *lev.shape[1:]) * sc[:, None]
            return out.reshape(lev.shape)

        fn = dq
        for _ in range(self._stack()):
            fn = jax.vmap(fn)
        w = fn(self.levels, self.scales)
        if self.conv_shape is not None:
            w = _conv_unview(w, self.conv_shape)
        return w.astype(dtype)

    # override QSQTensor.dequantize (axis-0 only) with the rank-aware decode
    def dequantize(self, dtype=jnp.float32):
        return self.as_dense(dtype)

    def matmul(self, x):
        return jnp.tensordot(x, self.as_dense(x.dtype), axes=1)

    def truncate(self, drop: int) -> "QSQWeight":
        """Level-space LSB plane truncation (see :func:`max_level_delta`).

        Maps each level through its sign-magnitude code (wire v2) with the
        ``drop`` lowest code bits zeroed — bit-identical to
        ``pack().truncate(drop)`` but applicable to any grouping (conv
        views included).  The sign plane survives every mask, so + and -
        levels degrade alike.  Scales are kept; no re-quantization happens.
        """
        if drop == 0:
            return self
        mask = _trunc_code_mask(drop)
        levels = smcodes_to_levels(levels_to_smcodes(self.levels) & mask)
        return dataclasses.replace(self, levels=levels)

    def pack(self, sign_mag: bool = True) -> "PackedWeight":
        """-> bit-plane form.  The grouped axis length must be 32-aligned.

        Planes carry sign-magnitude codes by default (wire v2: symmetric
        truncation); pass ``sign_mag=False`` for the legacy Table II
        planes."""
        if self.conv_shape is not None:
            raise ValueError("conv-view QSQ weights are not kernel-servable")
        to_codes = levels_to_smcodes if sign_mag else levels_to_codes

        def enc(lev):
            return codec.pack_bitplane(to_codes(lev))

        fn = enc
        for _ in range(self._stack()):
            fn = jax.vmap(fn)
        return PackedWeight(planes=fn(self.levels), scales=self.scales,
                            group_size=self.group_size, phi=self.phi,
                            rest_ndim=self._rest(), sign_mag=sign_mag)

    # nbits() inherited from QSQTensor (same accounting for any grouping).


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedWeight(WeightStore):
    """Bit-plane packed 3-bit codes + per-group scalars — the serving form.

    planes: (*stack, K//32, 3, *rest) int32, scales: (*stack, K//G, *rest)
    f32.  ``matmul`` routes through the shape-aware kernel dispatcher
    (``kernels/dispatch.py``): the GEMV kernel at decode shapes, the tiled
    GEMM otherwise (interpret mode off-TPU), with ragged shapes zero-padded
    to the fitted tile — dense weights never materialize in HBM; decode
    happens in VREGs next to the MXU, per the paper's Table II
    shift-and-scale decoder.

    ``n_planes`` counts the *significant* planes (3 = full quality).  A
    quality-tier truncation (:meth:`truncate`) zeroes the dropped LSB plane
    words in place of removing them — the physical 3-slot layout is what the
    fused kernel consumes — and ``nbits()`` accounts only the kept planes,
    which is what an edge receiver of the truncated wire would store.

    ``tier_drops`` (optional, static aux) is the leaf's per-quality-tier
    plane-drop vector — entry t = LSB planes a request at tier index t
    drops from THIS weight.  It powers per-request quality: the planes stay
    at full quality and :meth:`matmul` takes a per-row ``plane_mask``
    operand instead (``tier_plane_masks()[tiers]``), so one mixed-tier
    batch serves every row at its own tier with no param-tree swap and no
    retrace.  Being aux (not data), it is stack-invariant under layer
    scans, exactly like the grouping metadata.

    ``sign_mag`` marks planes carrying sign-magnitude codes (wire v2);
    default False keeps directly-constructed Table II planes decoding as
    before.  ``plane_major`` marks the demand-streaming layout
    (*stack, 3, K//32, *rest), plane axis outermost after the stack and
    MSB first — the planes a truncated tier keeps are a leading prefix, so
    the fused kernel's HBM read shortens with demand
    (:meth:`to_plane_major`).
    """

    planes: jax.Array
    scales: jax.Array
    group_size: int
    phi: int
    rest_ndim: int = 0
    n_planes: int = 3
    tier_drops: tuple[int, ...] | None = None
    sign_mag: bool = False
    plane_major: bool = False
    kind = "packed"

    def tree_flatten(self):
        return (self.planes, self.scales), (
            self.group_size, self.phi, self.rest_ndim, self.n_planes,
            self.tier_drops, self.sign_mag, self.plane_major,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        planes, scales = children
        return cls(planes=planes, scales=scales, group_size=aux[0], phi=aux[1],
                   rest_ndim=aux[2], n_planes=aux[3] if len(aux) > 3 else 3,
                   tier_drops=aux[4] if len(aux) > 4 else None,
                   sign_mag=bool(aux[5]) if len(aux) > 5 else False,
                   plane_major=bool(aux[6]) if len(aux) > 6 else False)

    def _stack(self) -> int:
        return self.planes.ndim - 2 - self.rest_ndim

    @property
    def shape(self):
        """Logical dense shape."""
        st = self._stack()
        k_axis = st + 1 if self.plane_major else st
        k = self.planes.shape[k_axis] * codec.PLANE_GROUP
        return self.planes.shape[:st] + (k,) + self.planes.shape[st + 2:]

    def to_plane_major(self) -> "PackedWeight":
        """-> the demand-streaming layout: plane axis before K//32, MSB
        first, so a dropped trailing plane shortens the kernel's HBM read
        (instead of being masked after the load).  Lossless; idempotent."""
        if self.plane_major:
            return self
        st = self._stack()
        pm = jnp.flip(jnp.moveaxis(self.planes, st + 1, st), axis=st)
        return dataclasses.replace(self, planes=pm, plane_major=True)

    def to_interleaved(self) -> "PackedWeight":
        """Inverse of :meth:`to_plane_major` (the legacy layout)."""
        if not self.plane_major:
            return self
        st = self._stack()
        il = jnp.moveaxis(jnp.flip(self.planes, axis=st), st, st + 1)
        return dataclasses.replace(self, planes=il, plane_major=False)

    def truncate(self, drop: int) -> "PackedWeight":
        """Plane-truncated view: zero the ``drop`` LSB bit-planes.

        ``drop`` counts from full quality, so the call is idempotent and
        re-resolving a tier never deepens an earlier truncation by accident.
        The view's ``as_dense``/``matmul``/``nbits`` all reflect the
        truncation; the error vs the full-quality weight is bounded by
        ``max_level_delta(drop) * alpha`` per group.  On a plane-major leaf
        the zeroed planes are the trailing ones, which the demand-routed
        kernel then never reads at all.
        """
        if drop == 0:
            return self
        if not 0 < drop < 3:
            raise ValueError(f"drop must be 0, 1 or 2; got {drop}")
        st = self._stack()
        if self.plane_major:
            idx = (slice(None),) * st + (slice(3 - drop, 3),)
        else:
            idx = (slice(None),) * (st + 1) + (slice(0, drop),)
        return dataclasses.replace(
            self, planes=self.planes.at[idx].set(0),
            n_planes=min(self.n_planes, 3 - drop),
        )

    def unpack(self) -> QSQWeight:
        to_levels = smcodes_to_levels if self.sign_mag else codes_to_levels
        if self.plane_major:
            def dec(pl_):
                return to_levels(codec.unpack_bitplane_major(pl_))
        else:
            def dec(pl_):
                return to_levels(codec.unpack_bitplane(pl_))

        fn = dec
        for _ in range(self._stack()):
            fn = jax.vmap(fn)
        return QSQWeight(levels=fn(self.planes), scales=self.scales,
                         group_size=self.group_size, phi=self.phi,
                         rest_ndim=self.rest_ndim)

    def as_dense(self, dtype=jnp.float32):
        return self.unpack().as_dense(dtype)

    def tier_plane_masks(self) -> jax.Array | None:
        """Per-tier 3-bit code masks from ``tier_drops`` (None when the leaf
        has no tier vector or no tier ever drops a plane from it).  Index
        with a per-slot tier array to get the per-row ``plane_mask``
        operand :meth:`matmul` takes."""
        if not self.tier_drops or not any(self.tier_drops):
            return None
        return jnp.asarray(
            [_trunc_code_mask(d) for d in self.tier_drops], jnp.int32
        )

    def demand_drop(self, demand_tier: int | None = None) -> int:
        """Static plane-drop floor for a batch whose minimum live tier index
        is ``demand_tier``: every live row at tier >= demand_tier drops at
        least ``min(tier_drops[demand_tier:])`` planes from this leaf, so
        the kernel can skip that many trailing planes outright.  Physical
        truncation (``n_planes < 3``) widens the floor on plane-major
        leaves, where skipping actually shortens the HBM read."""
        drop = 0
        if demand_tier is not None and self.tier_drops:
            t = min(max(int(demand_tier), 0), len(self.tier_drops) - 1)
            drop = min(self.tier_drops[t:])
        if self.plane_major:
            drop = max(drop, 3 - self.n_planes)
        return int(drop)

    def matmul(self, x, plane_mask: jax.Array | None = None,
               demand_tier: int | None = None):
        """Contract x (..., K) with this weight; optionally quality-tiered
        PER ROW.

        ``plane_mask`` holds one 3-bit code mask per leading-batch row of x
        (shape broadcastable over x's remaining lead dims, e.g. (B,) for a
        (B, S, K) x): row b's output is bit-identical to
        ``self.truncate(drop_b).matmul(x[b])`` — the tier dial as a masked
        term of the kernel's unpack, not a param swap.

        ``demand_tier`` (static python int) is the batch's minimum live
        tier index; combined with ``tier_drops`` it bounds how many
        trailing planes no row wants (:meth:`demand_drop`), and on
        plane-major leaves the kernel then streams only the demanded
        planes from HBM.  Every row's ``plane_mask`` must drop at least
        ``demand_drop`` planes — rows demanding a pruned variant read as
        zeros."""
        if self._stack():
            raise ValueError(
                "matmul on a stacked PackedWeight — slice the stack axis "
                "(e.g. via the layer scan) first"
            )
        rest = self.planes.shape[2:]
        k_words = self.planes.shape[1 if self.plane_major else 0]
        k = k_words * codec.PLANE_GROUP
        if x.shape[-1] != k:
            raise ValueError(f"x last dim {x.shape[-1]} != K {k}")
        n = int(np.prod(rest)) if rest else 1
        ng = self.scales.shape[0]
        g = k // ng
        lead = x.shape[:-1]
        m = int(np.prod(lead)) if lead else 1
        if plane_mask is not None:
            pm = jnp.asarray(plane_mask, jnp.int32)
            if pm.ndim > len(lead) or pm.shape != lead[: pm.ndim]:
                raise ValueError(
                    f"plane_mask shape {pm.shape} is not a leading prefix "
                    f"of x lead dims {lead}"
                )
            pm = pm.reshape(pm.shape + (1,) * (len(lead) - pm.ndim))
            plane_mask = jnp.broadcast_to(pm, lead if lead else (1,)).reshape(m)

        # Shape-aware kernel routing (kernels/dispatch.py): GEMV kernel at
        # decode shapes, tiled GEMM otherwise, zero-padded tiles for ragged
        # shapes, and the packed-representation XLA ref when the kernel
        # switch is off.  The dense weight is never materialized.
        from repro.kernels import dispatch  # deferred: pallas off cold paths

        pshape = (3, k_words, n) if self.plane_major else (k_words, 3, n)
        out = dispatch.packed_matmul(
            x.reshape(m, k),
            self.planes.reshape(pshape),
            self.scales.reshape(ng, n),
            group_size=g, use_kernel=_PACKED_MATMUL_KERNEL,
            plane_mask=plane_mask,
            sign_mag=self.sign_mag, plane_major=self.plane_major,
            demand_drop=self.demand_drop(demand_tier),
        )
        return out.astype(x.dtype).reshape(*lead, *rest)

    def nbits(self) -> int:
        kept_plane_words = (self.planes.size // 3) * self.n_planes
        return int(32 * (kept_plane_words + self.scales.size))


# The kernel routing switch: benchmarks/tests flip this to compare the fused
# kernel against the XLA dequant+matmul on identical PackedWeight trees.
_PACKED_MATMUL_KERNEL = True


def set_packed_matmul_kernel(enabled: bool) -> None:
    global _PACKED_MATMUL_KERNEL
    _PACKED_MATMUL_KERNEL = bool(enabled)


# --------------------------------------------------------------------------
# Tree-level: quantize under a policy (contraction-aware when descs given)
# --------------------------------------------------------------------------
def quantize_tree(params, policy: QuantPolicy, descs=None):
    """Quantize selected leaves of a param pytree -> QSQWeight leaves.

    With ``descs`` (the model's ParamDesc tree), kernel-eligible matmul
    weights are grouped along their true contraction axis — vmapped over
    leading scan-stack axes — which is the layout both the wire format and
    the serving kernel want.  Other selected leaves (and everything when
    ``descs`` is None) keep the legacy axis-0 grouping; 4-D conv kernels are
    grouped in the channel-major view (paper Fig. 5).
    """

    def _eligible_leaf(path, leaf, desc):
        idx = contract_idx(desc)
        cfg = policy.config_for(path, leaf.shape[idx:])
        if cfg is None:
            return leaf

        def enc(w):
            return _quantize_impl(
                w, phi=cfg.phi, group_size=cfg.group_size, assign=cfg.assign,
                delta=cfg.delta, gamma_frac=cfg.gamma_frac,
                refit_alpha=cfg.refit_alpha,
            )

        fn = enc
        for _ in range(idx):
            fn = jax.vmap(fn)
        levels, scales = fn(leaf)
        return QSQWeight(levels=levels, scales=scales,
                         group_size=cfg.group_size, phi=cfg.phi,
                         rest_ndim=leaf.ndim - idx - 1)

    def _legacy_leaf(path, leaf):
        view = _conv_view(leaf) if leaf.ndim == 4 else leaf
        cfg = policy.config_for(path, view.shape)
        if cfg is None:
            return leaf
        q = quantize(view, cfg)
        if leaf.ndim == 4:
            q = dataclasses.replace(q, conv_shape=tuple(leaf.shape))
        return QSQWeight.from_tensor(q, rest_ndim=q.levels.ndim - 1)

    if descs is None:
        return jax.tree_util.tree_map_with_path(
            lambda p, a: _legacy_leaf(path_str(p), a), params
        )

    def _leaf(path, leaf, desc):
        p = path_str(path)
        if _is_desc(desc) and kernel_eligible(p, desc):
            return _eligible_leaf(p, leaf, desc)
        return _legacy_leaf(p, leaf)

    return jax.tree_util.tree_map_with_path(_leaf, params, descs)


def dense_tree(tree, like=None):
    """Decode every WeightStore/QSQTensor leaf to dense (others untouched).

    ``like`` (optional matching pytree of arrays/ShapeDtypeStructs) supplies
    target dtypes; defaults to f32.  Plain :class:`QSQTensor` leaves (from
    direct ``core.qsq.quantize`` calls) decode with their legacy axis-0
    grouping, conv view included.
    """

    def _decodable(x):
        return is_store(x) or isinstance(x, QSQTensor)

    def _leaf(leaf, ref=None):
        dtype = ref.dtype if ref is not None else jnp.float32
        if is_store(leaf):
            return leaf.as_dense(dtype)
        if isinstance(leaf, QSQTensor):
            w = leaf.dequantize(dtype)
            if leaf.conv_shape is not None:
                w = _conv_unview(w, leaf.conv_shape)
            return w
        return leaf

    if like is None:
        return jax.tree_util.tree_map(_leaf, tree, is_leaf=_decodable)
    return jax.tree_util.tree_map(_leaf, tree, like, is_leaf=_decodable)


def packable_leaf(path: str, leaf, desc) -> bool:
    """True if this QSQ leaf can be served as bit-planes through the fused
    kernel: kernel-eligible per its descriptor AND wire-grouped along the
    contraction axis with a 32-aligned length (legacy axis-0 wires fall back
    to dense decode)."""
    return (
        isinstance(leaf, QSQWeight)
        and leaf.conv_shape is None
        and _is_desc(desc)
        and kernel_eligible(path, desc)
        and leaf._rest() == len(desc.shape) - contract_idx(desc) - 1
        and leaf.levels.shape[contract_idx(desc)] % codec.PLANE_GROUP == 0
    )


def serve_tree(tree, descs, dtype=None, drop_map=None, tier_drop_map=None):
    """Serving layout: pack kernel-eligible QSQ leaves, decode the rest.

    This is what a quality-tiered engine holds: matmul weights stay in
    3-bit bit-plane form end-to-end (decoded tile-by-tile inside the fused
    kernel), while gathered/sensitive leaves (embeddings, norms, wo, convs)
    are decoded once at load.  ``drop_map`` (path -> LSB planes to drop)
    applies a quality-tier truncation to the packed leaves it names —
    realized on the already-quantized codes, never by re-quantizing.
    ``tier_drop_map`` (path -> per-tier drop vector) instead KEEPS the
    planes at full quality and stamps the vector on the packed leaf as
    ``tier_drops``, enabling per-request tier masking at matmul time
    (see :meth:`PackedWeight.matmul`); leaves it does not name serve full
    quality at every tier.  Returns (params_tree, n_packed).
    """
    n_packed = 0
    drop_map = drop_map or {}
    tier_drop_map = tier_drop_map or {}

    def _leaf(path, leaf, desc):
        nonlocal n_packed
        if not is_store(leaf):
            return leaf
        p = path_str(path)
        if packable_leaf(p, leaf, desc):
            n_packed += 1
            # sign-magnitude planes in the plane-major layout: truncation is
            # symmetric in sign, and dropped/undemanded trailing planes
            # shorten the kernel's HBM read instead of being masked.
            pw = leaf.pack().truncate(drop_map.get(p, 0)).to_plane_major()
            if p in tier_drop_map:
                pw = dataclasses.replace(
                    pw, tier_drops=tuple(int(d) for d in tier_drop_map[p])
                )
            return pw
        want = dtype if dtype is not None else getattr(desc, "dtype", jnp.float32)
        if p in drop_map:
            leaf = leaf.truncate(drop_map[p]) if isinstance(leaf, QSQWeight) else leaf
        return leaf.as_dense(want)

    out = jax.tree_util.tree_map_with_path(
        _leaf, tree, descs, is_leaf=lambda x: is_store(x)
    )
    return out, n_packed


def truncate_tree(tree, drop_map: dict):
    """Apply per-path LSB plane truncation to QSQ/packed leaves of a tree.

    ``drop_map`` maps '/'-joined pytree paths to planes-to-drop (from full
    quality).  Leaves not named, and leaves with no truncatable form, pass
    through untouched.
    """

    def _leaf(path, leaf):
        drop = drop_map.get(path_str(path), 0)
        if drop and isinstance(leaf, (QSQWeight, PackedWeight)):
            return leaf.truncate(drop)
        return leaf

    return jax.tree_util.tree_map_with_path(_leaf, tree, is_leaf=is_store)


def tree_bits_report(tree) -> dict:
    """Eq. 11/12 accounting over a mixed-representation tree."""
    total_bits = 0
    dense_bits = 0
    n_store = 0
    n_total = 0
    for leaf in jax.tree_util.tree_leaves(tree, is_leaf=is_store):
        n_total += 1
        if is_store(leaf):
            n_store += 1
            total_bits += leaf.nbits()
            dense_bits += int(8 * 4 * np.prod(leaf.shape))  # vs f32
        else:
            b = int(8 * leaf.size * jnp.dtype(leaf.dtype).itemsize)
            total_bits += b
            dense_bits += b
    return {
        "bits": total_bits,
        "dense_bits": dense_bits,
        "savings": 1.0 - total_bits / max(dense_bits, 1),
        "n_store_leaves": n_store,
        "n_leaves": n_total,
    }


# --------------------------------------------------------------------------
# Wire form: QSQWeight <-> {packed int32 words, scales, meta} dict.
# One codec for checkpoint export, DCN transfer and the serving load path.
# --------------------------------------------------------------------------
WIRE_FLAG = "__qsq__"

# Wire code formats: 1 = Table II offset codes (legacy, implied when the
# key is absent), 2 = sign-magnitude codes (symmetric plane truncation).
WIRE_CODE_FMT = 2


def is_wire_leaf(x) -> bool:
    return isinstance(x, dict) and bool(x.get(WIRE_FLAG, False))


def wire_encode_leaf(q: QSQTensor) -> dict:
    """Any QSQTensor/QSQWeight -> the dense-packed 3-bit wire dict.

    Wire v2: codes are sign-magnitude (``code_fmt: 2``), so an edge
    receiver can truncate LSB planes off the stream with + and - levels
    degrading alike.  :func:`wire_decode_leaf` still reads legacy v1
    (Table II) dicts, which carry no ``code_fmt`` key."""
    codes = levels_to_smcodes(q.levels).reshape(-1)
    rest = q.rest_ndim if isinstance(q, QSQWeight) and q.rest_ndim is not None \
        else q.levels.ndim - 1
    return {
        WIRE_FLAG: True,
        "packed": codec.pack_dense(codes, bits=3),
        "scales": q.scales,
        "shape": tuple(int(s) for s in q.levels.shape),
        "group_size": int(q.group_size),
        "phi": int(q.phi),
        "rest_ndim": int(rest),
        "conv_shape": tuple(int(s) for s in q.conv_shape) if q.conv_shape else (),
        "code_fmt": WIRE_CODE_FMT,
    }


def wire_decode_leaf(d: dict) -> QSQWeight:
    """Inverse of :func:`wire_encode_leaf` (lossless: codes + scales exact).

    Tolerates legacy wire dicts (no rest_ndim => axis-0 grouping; no
    code_fmt => Table II offset codes) and npz-roundtripped metadata
    (numpy scalars/arrays instead of ints/tuples).
    """
    shape = tuple(int(s) for s in np.asarray(d["shape"]).reshape(-1))
    n = int(np.prod(shape)) if shape else 1
    codes = codec.unpack_dense(jnp.asarray(d["packed"]), n).reshape(shape)
    conv = tuple(int(s) for s in np.asarray(d.get("conv_shape", ())).reshape(-1))
    rest = d.get("rest_ndim", None)
    fmt_raw = d.get("code_fmt", None)
    fmt = int(np.asarray(fmt_raw)) if fmt_raw is not None else 1
    if fmt not in (1, WIRE_CODE_FMT):
        raise ValueError(f"unknown wire code_fmt {fmt}")
    to_levels = smcodes_to_levels if fmt == WIRE_CODE_FMT else codes_to_levels
    return QSQWeight(
        levels=to_levels(codes),
        scales=jnp.asarray(d["scales"]),
        group_size=int(d["group_size"]),
        phi=int(d["phi"]),
        conv_shape=conv if conv else None,
        rest_ndim=int(np.asarray(rest)) if rest is not None else None,
    )


def tree_to_wire(tree) -> Any:
    """Store tree -> wire tree (raw leaves pass through untouched)."""

    def _leaf(leaf):
        if isinstance(leaf, PackedWeight):
            return wire_encode_leaf(leaf.unpack())
        if isinstance(leaf, QSQTensor):
            return wire_encode_leaf(leaf)
        if isinstance(leaf, DenseWeight):
            return leaf.value
        return leaf

    return jax.tree_util.tree_map(
        _leaf, tree, is_leaf=lambda x: is_store(x) or isinstance(x, QSQTensor)
    )


def tree_from_wire(wire) -> Any:
    """Wire tree -> store tree with QSQWeight leaves."""
    return jax.tree_util.tree_map(
        lambda x: wire_decode_leaf(x) if is_wire_leaf(x) else x,
        wire, is_leaf=is_wire_leaf,
    )

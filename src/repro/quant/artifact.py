"""EdgeArtifact: one quality-dialed facade from policy → wire → engine.

The paper's headline is *quality scalability* — per-layer phi levels plus
CSD LSB truncation trade accuracy for energy/memory.  This module makes
that a single API surface instead of six hand-composed entry points:

    art = compress(model, params)            # policy -> 3-bit wire + tiers
    art.save("model.edge.npz")               # self-describing artifact
    art = EdgeArtifact.load("model.edge.npz")
    eng = art.engine(quality="mid")          # serve at a named tier
    eng.set_quality("lo")                    # re-dial without reloading

Quality tiers are *real*, not cosmetic: ``compress`` quantizes once at full
quality and stores a per-layer sensitivity ranking; a lower tier is then
realized at serve time by dropping LSB bit-planes from the packed weights
of the least-sensitive layers (``PackedWeight.truncate`` — the progressive
wire analogue of the paper's CSD LSB truncation).  No tier ever
re-quantizes, so every tier of one artifact shares one set of codes and
scalars on disk.

The npz layout is a superset of the old ``CheckpointManager.export_wire``
format: the same flat wire keys plus one ``__edge_meta__`` JSON entry
(arch config, tier spec, sensitivity ranking).  ``export_wire``/
``load_wire`` delegate here, and bare wire files still load (they just
carry no arch/tier metadata).
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
import warnings
from pathlib import Path
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, HybridConfig, MoEConfig
from repro.core import codec
from repro.core.policy import QuantPolicy, budgeted_policy, path_str
from repro.core.qsq import QSQConfig
from repro.quant.store import (
    QSQWeight,
    dense_tree,
    is_store,
    is_wire_leaf,
    max_level_delta,
    packable_leaf,
    plane_mask_for_drop,
    quantize_tree,
    tree_from_wire,
    tree_to_wire,
    truncate_tree,
)

META_KEY = "__edge_meta__"
FORMAT = "edge-artifact-v1"
N_PLANES = 3  # 3-bit wire: sign/MSB, mid, LSB


class ArtifactIntegrityError(ValueError):
    """Checksum verification found damage no quality tier can absorb —
    a corrupted sign/MSB plane, or LSB damage deeper than any tier's
    plane drops.  Trailing-LSB damage within tier reach never raises:
    the artifact loads with a capped tier ceiling instead."""


# --------------------------------------------------------------------------
# Quality tiers
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class QualityTier:
    """One position of the quality dial.

    ``drop_planes`` LSB code planes are dropped from the least-sensitive
    ``drop_frac`` fraction of the artifact's packable matmul weights (most
    sensitive layers keep full quality, mirroring the paper's per-layer phi
    assignment).  ``drop_planes=0`` is full quality.
    """

    name: str
    drop_planes: int = 0
    drop_frac: float = 1.0

    def max_error_levels(self) -> int:
        """Per-weight error bound of this tier, in level units (x alpha)."""
        return max_level_delta(self.drop_planes)


@dataclasses.dataclass(frozen=True)
class QualitySpec:
    """The named tiers one artifact can serve, best quality first."""

    tiers: tuple[QualityTier, ...]

    def names(self) -> list[str]:
        return [t.name for t in self.tiers]

    def get(self, name: str) -> QualityTier:
        for t in self.tiers:
            if t.name == name:
                return t
        raise KeyError(
            f"unknown quality tier {name!r}; this artifact has {self.names()}"
        )


DEFAULT_TIERS = QualitySpec((
    QualityTier("hi", drop_planes=0, drop_frac=0.0),
    QualityTier("mid", drop_planes=1, drop_frac=0.5),
    QualityTier("lo", drop_planes=1, drop_frac=1.0),
))


# --------------------------------------------------------------------------
# ArchConfig <-> JSON (self-describing artifacts rebuild their Model)
# --------------------------------------------------------------------------
def _arch_to_json(cfg: ArchConfig) -> dict:
    d = dataclasses.asdict(cfg)
    d["dtype"] = jnp.dtype(cfg.dtype).name
    return d


def _arch_from_json(d: dict) -> ArchConfig:
    known = {f.name for f in dataclasses.fields(ArchConfig)}
    d = {k: v for k, v in d.items() if k in known}
    d["dtype"] = jnp.dtype(d["dtype"])
    if d.get("moe"):
        d["moe"] = MoEConfig(**d["moe"])
    if d.get("hybrid"):
        d["hybrid"] = HybridConfig(**d["hybrid"])
    return ArchConfig(**d)


# --------------------------------------------------------------------------
# npz wire codec (single source for checkpoint export and artifact save)
# --------------------------------------------------------------------------
_KEY_RE = re.compile(r"\['([^']*)'\]|\[(\d+)\]")


def flatten_keystr(tree) -> dict:
    """Pytree -> {jax keystr path: host numpy leaf} (npz-ready)."""
    return {
        jax.tree_util.keystr(p): np.asarray(jax.device_get(leaf))
        for p, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]
    }


def atomic_savez(flat: dict, path: Path) -> Path:
    """Write an npz via tmp-file + rename so a crashed writer can never
    corrupt an existing file.  Shared by checkpoint saves and artifacts."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(".tmp.npz")
    np.savez(tmp, **flat)
    tmp.rename(path)
    return path


def save_wire_npz(wire, path: str | Path, meta: dict | None = None) -> Path:
    """Atomically write a wire pytree (plus optional JSON meta) as npz."""
    flat = flatten_keystr(wire)
    if meta is not None:
        flat[META_KEY] = np.array(json.dumps(meta))
    return atomic_savez(flat, Path(path))


def load_wire_npz(path: str | Path) -> tuple[Any, dict | None]:
    """Inverse of :func:`save_wire_npz` -> (nested wire tree, meta or None).

    Codes and scales round-trip bit-exactly; int-keyed levels (flattened
    tuples/lists such as wire 'shape' entries) come back as lists.
    """
    data = np.load(Path(path), allow_pickle=False)
    meta = None
    root: dict = {}
    for key in data.files:
        if key == META_KEY:
            meta = json.loads(str(data[key][()]))
            continue
        parts = [m.group(1) if m.group(1) is not None else int(m.group(2))
                 for m in _KEY_RE.finditer(key)]
        if not parts:
            continue
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = data[key]

    def _listify(node):
        if not isinstance(node, dict):
            return node
        out = {k: _listify(v) for k, v in node.items()}
        if out and all(isinstance(k, int) for k in out):
            return [out[i] for i in sorted(out)]
        return out

    return _listify(root), meta


# --------------------------------------------------------------------------
# The artifact
# --------------------------------------------------------------------------
@dataclasses.dataclass
class EdgeArtifact:
    """A quality-dialed compressed model: wire tree + tiers + arch identity.

    ``wire`` is the 3-bit + scalar pytree (the channel payload).  ``rank``
    is the per-layer sensitivity ordering, most sensitive first, over the
    packable matmul weights (or all quantized leaves for model-free
    artifacts such as the paper's CNNs); tiers resolve against it
    deterministically, so a saved artifact serves identical tokens after
    ``load``.
    """

    wire: Any
    arch_config: ArchConfig | None = None
    tiers: QualitySpec = DEFAULT_TIERS
    rank: tuple = ()  # ((path, sensitivity_score), ...) most sensitive first
    policy_meta: dict = dataclasses.field(default_factory=dict)
    # degraded-wire bookkeeping, set by load(verify=True): path -> LSB
    # planes that had to be zeroed because their stored checksums did not
    # match (channel corruption or a truncated download).  Empty = pristine.
    plane_damage: dict = dataclasses.field(default_factory=dict)

    # -- identity ---------------------------------------------------------
    @property
    def arch(self) -> str:
        return self.arch_config.name if self.arch_config is not None else ""

    def model(self):
        """Rebuild the serving Model from the stored arch config."""
        if self.arch_config is None:
            raise ValueError(
                "this artifact carries no arch config (model-free compress "
                "or legacy bare wire); use dense_params()/tree() instead"
            )
        from repro.models.api import Model  # deferred: models -> quant cycle

        return Model(self.arch_config)

    def quality_names(self) -> list[str]:
        return self.tiers.names()

    # -- tier resolution --------------------------------------------------
    def drop_map(self, quality: str) -> dict[str, int]:
        """Tier name -> {path: LSB planes to drop}, least sensitive first."""
        tier = self.tiers.get(quality)
        if tier.drop_planes <= 0 or tier.drop_frac <= 0:
            return {}
        if not self.rank:
            # refusing beats silently serving full quality under a lower
            # tier's name (bare checkpoint wires / the from_wire shim carry
            # no ranking to resolve the tier against)
            raise ValueError(
                f"quality tier {quality!r} needs a sensitivity ranking to "
                f"pick truncation targets, but this artifact has none "
                f"(legacy bare wire?); rebuild it with repro.api.compress()"
            )
        paths = [p for p, _ in self.rank]  # most sensitive first
        n_aff = min(len(paths), max(1, math.ceil(tier.drop_frac * len(paths))))
        return {p: tier.drop_planes for p in paths[len(paths) - n_aff:]}

    def tier_drop_vectors(self) -> dict[str, tuple[int, ...]]:
        """Path -> per-tier plane-drop vector (entry t = planes tier index
        t drops from that weight), over every path any tier truncates.

        This is what per-request quality serves from: one full-quality
        packed tree where each affected leaf knows how many LSB planes
        each tier masks off — the tier dial becomes a per-row plane mask
        inside the kernel instead of a param-tree swap."""
        n = len(self.tiers.tiers)
        out: dict[str, list[int]] = {}
        for i, tier in enumerate(self.tiers.tiers):
            for p, d in self.drop_map(tier.name).items():
                out.setdefault(p, [0] * n)[i] = int(d)
        return {p: tuple(v) for p, v in out.items()}

    # -- per-plane integrity (degraded-wire serving) ----------------------
    def _wire_leaves(self) -> list[tuple[str, dict]]:
        """('/'-joined path, wire leaf dict) for every packed wire leaf —
        the same path strings ``rank``/``drop_map`` resolve against."""
        return [
            (path_str(p), leaf)
            for p, leaf in jax.tree_util.tree_flatten_with_path(
                self.wire, is_leaf=is_wire_leaf)[0]
            if is_wire_leaf(leaf)
        ]

    @staticmethod
    def _leaf_codes(leaf: dict) -> np.ndarray:
        n = int(np.prod(np.asarray(leaf["shape"]).reshape(-1)))
        return np.asarray(codec.unpack_dense(jnp.asarray(leaf["packed"]), n))

    def plane_integrity(self) -> dict[str, list[int]]:
        """Path -> per-plane CRC32s (MSB first) over each wire leaf's
        codes; stored in the artifact meta by :meth:`save` so a receiver
        can tell exactly which bit-planes the channel damaged."""
        return {
            p: list(codec.plane_crcs(self._leaf_codes(leaf)))
            for p, leaf in self._wire_leaves()
        }

    def _verify_integrity(self, stored: dict) -> None:
        """Check every wire leaf's per-plane CRCs against the stored ones
        and REPAIR what the tier ladder can absorb: a damaged trailing
        LSB plane is zeroed in place (bit-identical to a truncated
        plane-major download — the paper's channel degrading the stream
        IS the quality dial) and recorded in ``plane_damage`` so serving
        caps the tier ceiling.  Damage to the sign/MSB plane — or any
        damage pattern the tiers cannot cover — raises
        :class:`ArtifactIntegrityError`."""
        damage: dict[str, int] = {}
        for p, leaf in self._wire_leaves():
            want = stored.get(p)
            if want is None:
                continue
            codes = self._leaf_codes(leaf)
            got = codec.plane_crcs(codes)
            bad = [i for i in range(N_PLANES)
                   if got[i] != int(want[i]) & 0xFFFFFFFF]
            if not bad:
                continue
            if 0 in bad:
                raise ArtifactIntegrityError(
                    f"wire leaf {p!r}: sign/MSB plane failed its checksum "
                    f"— unrecoverable; re-download the artifact"
                )
            # MSB-first plane index i damaged => the leaf is only valid
            # with the bottom (N_PLANES - i) planes gone
            need = max(N_PLANES - i for i in bad)
            repaired = codes & np.uint8(plane_mask_for_drop(need))
            leaf["packed"] = np.asarray(codec.pack_dense(repaired, bits=3))
            damage[p] = need
        self.plane_damage = damage

    def tier_ceiling_index(self) -> int:
        """Best (lowest) tier index this artifact can still serve: the
        first tier whose :meth:`drop_map` truncates every damaged leaf at
        least as deep as its zeroed planes — at that tier the repaired
        artifact is BIT-IDENTICAL to a pristine one.  0 when pristine;
        raises when even the lowest tier leaves damage exposed."""
        if not self.plane_damage:
            return 0
        for t, tier in enumerate(self.tiers.tiers):
            dm = self.drop_map(tier.name)
            if all(dm.get(p, 0) >= need
                   for p, need in self.plane_damage.items()):
                return t
        raise ArtifactIntegrityError(
            f"plane damage {self.plane_damage} exceeds every quality "
            f"tier's truncation ({self.quality_names()}); the artifact "
            f"cannot be served — re-download"
        )

    def degraded_quality(self, quality: str) -> tuple[str, int]:
        """(serve tier, ceiling index) under this artifact's plane damage:
        tiers above the ceiling clamp DOWN to it (degrade, don't fail),
        with a warning naming the substitution."""
        ceiling = self.tier_ceiling_index()
        names = self.quality_names()
        if names.index(quality) < ceiling:
            warnings.warn(
                f"artifact plane damage {self.plane_damage} caps serving "
                f"at tier {names[ceiling]!r}; requested {quality!r} is "
                f"degraded to it",
                stacklevel=3,
            )
            quality = names[ceiling]
        return quality, ceiling

    # -- realization ------------------------------------------------------
    def tree(self):
        """Decode the wire to a WeightStore tree (QSQWeight leaves)."""
        return tree_from_wire(self.wire)

    def serve_params(self, quality: str = "hi", packed: bool = True,
                     per_request: bool = False):
        """(params, n_packed) at a tier — matmul weights stay bit-planes.

        With ``per_request`` the planes stay FULL quality and every
        tier-affected leaf carries its :meth:`tier_drop_vectors` entry, so
        one tree serves any tier per matmul row; ``quality`` then only
        names the default tier (validated here)."""
        if per_request:
            self.tiers.get(quality)  # validate the default tier name
            return self.model().serve_params(
                self.wire, packed=True,
                tier_drop_map=self.tier_drop_vectors(),
            )
        return self.model().serve_params(
            self.wire, packed=packed, drop_map=self.drop_map(quality)
        )

    def dense_params(self, quality: str = "hi", like=None):
        """Fully decoded param tree at a tier (model-free path: CNNs etc.).
        Plane-damaged artifacts clamp ``quality`` to the tier ceiling."""
        if self.plane_damage:
            quality, _ = self.degraded_quality(quality)
        store = truncate_tree(self.tree(), self.drop_map(quality))
        return dense_tree(store, like=like)

    def _per_request_capable(self, cfg) -> bool:
        """True when an engine under ``cfg`` can serve per-request tiers:
        packed continuous greedy serving on an attention family, with a
        sensitivity ranking to resolve the tier drop maps against (or a
        tier spec that never drops — then every tier is the full wire)."""
        from repro.train.step import supports_fused_prefill

        if not (cfg.packed and cfg.continuous and cfg.temperature == 0):
            return False
        if self.arch_config is None or not supports_fused_prefill(self.model()):
            return False
        drops_any = any(
            t.drop_planes > 0 and t.drop_frac > 0 for t in self.tiers.tiers
        )
        return bool(self.rank) or not drops_any

    def engine(self, quality: str = "hi", serve_cfg=None,
               per_request: bool | None = None, **serve_kw):
        """Build a ServeEngine at a named tier.

        ``serve_kw`` forwards to ``ServeConfig`` (batch_slots, max_len,
        temperature, packed); pass ``serve_cfg`` to reuse an existing
        config (mutually exclusive with ``serve_kw``).  The engine keeps a
        handle to this artifact, so ``engine.set_quality(q)`` re-dials the
        tier in place without reloading or re-quantizing.

        ``per_request`` controls PER-REQUEST quality.  Default (None):
        enabled whenever the engine can serve it (packed continuous greedy
        attention-family serving with a sensitivity ranking) — the packed
        tree then stays at full quality with per-tier drop vectors on each
        leaf, ``quality`` is just the default tier, and
        ``submit(..., quality=...)`` admits each request at its own tier
        into the one mixed-tier decode dispatch.  ``False`` forces the
        single-tier layout (physically plane-truncated params — what an
        edge receiver of the truncated wire would hold, and what
        ``nbits()`` savings are measured on).  ``True`` raises if the
        config cannot serve per-request tiers."""
        from repro.serve.engine import ServeConfig, ServeEngine

        if serve_cfg is not None and serve_kw:
            raise TypeError(
                f"pass either serve_cfg or ServeConfig kwargs, not both "
                f"(got serve_cfg and {sorted(serve_kw)})"
            )
        cfg = serve_cfg if serve_cfg is not None else ServeConfig(**serve_kw)
        if per_request is None:
            per_request = self._per_request_capable(cfg)
        elif per_request and not self._per_request_capable(cfg):
            raise ValueError(
                "per-request quality needs packed continuous greedy "
                "serving of an attention family, from an artifact with a "
                "sensitivity ranking (repro.api.compress)"
            )
        ceiling = 0
        if self.plane_damage:
            # degraded wire: serve the best tier the surviving planes
            # support instead of failing (a truncated download IS a tier)
            quality, ceiling = self.degraded_quality(quality)
        params, n_packed = self.serve_params(quality, packed=cfg.packed,
                                             per_request=per_request)
        eng = ServeEngine(self.model(), params, cfg)
        eng.n_packed_leaves = n_packed
        eng.artifact = self
        eng.quality = quality
        if per_request:
            eng.tier_names = self.quality_names()
            eng.tier_ceiling = ceiling
        return eng

    # -- persistence ------------------------------------------------------
    def save(self, path: str | Path) -> Path:
        """Write the self-describing artifact npz (wire + tiers + arch +
        per-plane checksums for degraded-wire recovery at load)."""
        meta = {
            "format": FORMAT,
            "arch": _arch_to_json(self.arch_config)
            if self.arch_config is not None else None,
            "tiers": [dataclasses.asdict(t) for t in self.tiers.tiers],
            "rank": [[p, float(s)] for p, s in self.rank],
            "policy": self.policy_meta,
            "integrity": self.plane_integrity(),
        }
        return save_wire_npz(self.wire, path, meta)

    @classmethod
    def load(cls, path: str | Path, verify: bool = True) -> "EdgeArtifact":
        """Read an artifact npz; bare (legacy) wire files load with no
        arch/tier metadata and serve only through ``dense_params``/
        ``tree()`` or an explicitly supplied model.

        With ``verify`` (default) and stored per-plane checksums, every
        wire leaf is integrity-checked: intact artifacts load unchanged;
        trailing-LSB damage (corruption or a partial download) is zeroed
        in place and CAPS the serving tier (``plane_damage`` /
        ``tier_ceiling_index``) — bit-identical to a deliberately
        truncated artifact — while sign/MSB damage raises
        :class:`ArtifactIntegrityError`.  Artifacts saved before
        checksums existed skip verification."""
        wire, meta = load_wire_npz(path)
        if meta is None:
            return cls(wire=wire)
        art = cls(
            wire=wire,
            arch_config=_arch_from_json(meta["arch"]) if meta.get("arch") else None,
            tiers=QualitySpec(tuple(QualityTier(**t) for t in meta["tiers"]))
            if meta.get("tiers") else DEFAULT_TIERS,
            rank=tuple((p, s) for p, s in meta.get("rank", [])),
            policy_meta=meta.get("policy", {}),
        )
        if verify and meta.get("integrity"):
            art._verify_integrity(meta["integrity"])
        return art


# --------------------------------------------------------------------------
# compress: policy -> wire -> artifact (the facade's entry point)
# --------------------------------------------------------------------------
def default_policy() -> QuantPolicy:
    """The serving-grade default: contraction-grouped 3-bit QSQ with the
    beyond-paper alpha refit (same wire format, several-fold lower error)."""
    return QuantPolicy(
        base=QSQConfig(group_size=16, refit_alpha=True), min_numel=512
    )


def _proxy_rank(params, store, descs) -> list[tuple[str, float]]:
    """Data-free sensitivity proxy: relative quantization error per leaf.

    Ranks the truncation candidates (packable leaves when descriptors are
    available, every quantized leaf otherwise) by
    ||w - dequant(w)||^2 / ||w||^2, descending — layers the 3-bit code
    already hurts most are the ones a tier should protect from further LSB
    truncation.  ``sensitivity_rank`` (calibration-data-driven) can replace
    this via ``compress(..., sensitivity=...)``.
    """
    flat_p = {path_str(p): leaf
              for p, leaf in jax.tree_util.tree_flatten_with_path(params)[0]}
    desc_map = {}
    if descs is not None:
        desc_map = {path_str(p): d for p, d in
                    jax.tree_util.tree_flatten_with_path(descs)[0]}
    scores = []
    for p, leaf in jax.tree_util.tree_flatten_with_path(
            store, is_leaf=is_store)[0]:
        ps = path_str(p)
        if not isinstance(leaf, QSQWeight):
            continue
        if descs is not None and not packable_leaf(ps, leaf, desc_map.get(ps)):
            continue
        w = np.asarray(flat_p[ps], dtype=np.float32)
        err = np.asarray(leaf.as_dense(jnp.float32), dtype=np.float32) - w
        scores.append((ps, float(np.sum(err * err) /
                                 (np.sum(w * w) + 1e-12))))
    return sorted(scores, key=lambda t: -t[1])


def compress(
    model,
    params,
    policy: QuantPolicy | None = None,
    tiers: QualitySpec = DEFAULT_TIERS,
    sensitivity: Sequence[tuple[str, float]] | None = None,
) -> EdgeArtifact:
    """Quantize a model once and return the quality-dialed EdgeArtifact.

    ``model`` is a ``repro.models.api.Model`` (its descriptors group matmul
    weights along the contraction axis, the serving-kernel layout) or None
    for model-free trees (the paper's CNNs): then the artifact supports
    ``dense_params`` but not ``engine``.

    ``sensitivity`` is an optional calibration ranking from
    ``core.policy.sensitivity_rank`` (most sensitive first).  When given it
    does double duty, exactly as the paper uses its per-layer search: it is
    folded into the policy as per-layer phi overrides
    (``budgeted_policy``), and it orders the tier truncation so low tiers
    degrade the least-sensitive layers first.  Without it a data-free proxy
    ranking (per-layer relative quantization error) orders the tiers.
    """
    policy = policy if policy is not None else default_policy()
    if sensitivity:
        policy = budgeted_policy(list(sensitivity), policy)
    descs = model.param_descs() if model is not None else None
    store = quantize_tree(params, policy, descs)
    rank = (tuple((p, float(s)) for p, s in sensitivity) if sensitivity
            else tuple(_proxy_rank(params, store, descs)))
    return EdgeArtifact(
        wire=tree_to_wire(store),
        arch_config=model.cfg if model is not None else None,
        tiers=tiers,
        rank=rank,
        policy_meta={
            "phi": policy.base.phi,
            "group_size": policy.base.group_size,
            "assign": policy.base.assign,
            "refit_alpha": policy.base.refit_alpha,
            "n_overrides": len(policy.overrides),
            "calibrated": bool(sensitivity),
        },
    )

"""Applying QSQ to whole model pytrees (quantize / dequantize / packed store)."""
from repro.quant.pytree import (
    QuantizedParams,
    dequantize_pytree,
    pack_pytree_wire,
    pytree_bits_report,
    quantize_pytree,
    unpack_pytree_wire,
)

__all__ = [
    "QuantizedParams",
    "quantize_pytree",
    "dequantize_pytree",
    "pytree_bits_report",
    "pack_pytree_wire",
    "unpack_pytree_wire",
]

from repro.quant.store import (
    DenseWeight,
    PackedWeight,
    QSQWeight,
    WeightStore,
    dense_tree,
    is_store,
    max_level_delta,
    plane_mask_for_drop,
    quantize_tree,
    serve_tree,
    set_packed_matmul_kernel,
    tree_bits_report,
    tree_from_wire,
    tree_to_wire,
    truncate_tree,
)

__all__ += [
    "WeightStore", "DenseWeight", "QSQWeight", "PackedWeight", "is_store",
    "quantize_tree", "dense_tree", "serve_tree", "tree_bits_report",
    "tree_to_wire", "tree_from_wire", "set_packed_matmul_kernel",
    "truncate_tree", "max_level_delta", "plane_mask_for_drop",
]

from repro.quant.artifact import DEFAULT_TIERS, EdgeArtifact, QualitySpec, QualityTier, compress

__all__ += [
    "EdgeArtifact", "QualitySpec", "QualityTier", "DEFAULT_TIERS", "compress",
]

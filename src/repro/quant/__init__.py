"""Applying QSQ to whole model pytrees (quantize / dequantize / packed store)."""
from repro.quant.pytree import (
    QuantizedParams,
    quantize_pytree,
    dequantize_pytree,
    pytree_bits_report,
    pack_pytree_wire,
    unpack_pytree_wire,
)

__all__ = [
    "QuantizedParams",
    "quantize_pytree",
    "dequantize_pytree",
    "pytree_bits_report",
    "pack_pytree_wire",
    "unpack_pytree_wire",
]

from repro.train.state import TrainState, train_state_descs
from repro.train.step import (
    make_cache_prefill_step,
    make_decode_loop,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)

from repro.train.state import TrainState, train_state_descs
from repro.train.step import make_train_step, make_prefill_step, make_serve_step

"""Fault-tolerant training loop.

Production concerns handled here (unit-tested on CPU, designed for pods):

* checkpoint/restart: atomic checkpoints every K steps, resume-from-latest
  including the data-iterator state — a killed run continues bit-exactly.
* preemption: SIGTERM triggers a final checkpoint before exit (the TPU
  maintenance-event pattern).
* straggler watchdog: per-step wall time is tracked against a running
  median; outlier steps are logged as straggler events (on a real fleet this
  feeds the pod-replacement controller; here it is observable behavior that
  tests inject delays into).
* grad compression: QSQ on gradients with error feedback (optim/compression).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable

import jax
import numpy as np

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.models.api import Model
from repro.models.base import init_params
from repro.optim import AdamWConfig, GradCompressionConfig
from repro.train.state import TrainState, train_state_descs
from repro.train.step import make_train_step


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    total_steps: int = 100
    log_every: int = 10
    seed: int = 0
    straggler_factor: float = 3.0  # step > factor * running median => event
    opt: AdamWConfig = AdamWConfig()
    compression: GradCompressionConfig = GradCompressionConfig()
    checkpoint: CheckpointConfig | None = None


class Trainer:
    def __init__(self, model: Model, cfg: TrainerConfig,
                 batch_fn: Callable[[int], dict]):
        """batch_fn(step) -> batch dict (pure function => resumable stream)."""
        self.model = model
        self.cfg = cfg
        self.batch_fn = batch_fn
        self.step_fn = jax.jit(
            make_train_step(model, cfg.opt, cfg.compression, cfg.total_steps),
            donate_argnums=(0,),
        )
        self.ckpt = CheckpointManager(cfg.checkpoint) if cfg.checkpoint else None
        self.straggler_events: list[dict] = []
        self.metrics_log: list[dict] = []
        self._preempted = False

    # -- state ------------------------------------------------------------
    def init_state(self) -> tuple[TrainState, int]:
        descs = train_state_descs(self.model, self.cfg.compression)
        state = init_params(jax.random.PRNGKey(self.cfg.seed), descs)
        start = 0
        if self.ckpt is not None:
            restored, meta = self.ckpt.restore(state)
            if restored is not None:
                state, start = restored, int(meta["step"])
        return state, start

    # -- preemption ---------------------------------------------------------
    def _install_preemption_handler(self):
        def handler(signum, frame):  # noqa: ARG001
            self._preempted = True

        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass  # not on main thread (tests)

    def request_preemption(self):
        """Programmatic preemption trigger (used by tests)."""
        self._preempted = True

    # -- loop ---------------------------------------------------------------
    def run(self, state: TrainState | None = None, start_step: int | None = None,
            step_hook: Callable | None = None):
        """Train until total_steps or preemption.  Returns (state, last_step)."""
        if state is None or start_step is None:
            state, start_step = self.init_state()
        self._install_preemption_handler()

        durations: list[float] = []
        step = start_step
        for step in range(start_step, self.cfg.total_steps):
            t0 = time.time()
            batch = self.batch_fn(step)
            state, metrics = self.step_fn(state, batch)
            # block so wall time (and straggler detection) is real
            loss = float(metrics["loss"])
            if step_hook is not None:
                step_hook(step, state, metrics)
            # duration includes the hook so tests can inject straggler delays
            dt = time.time() - t0

            # straggler watchdog
            if len(durations) >= 5:
                med = float(np.median(durations[-50:]))
                if dt > self.cfg.straggler_factor * med:
                    self.straggler_events.append(
                        {"step": step, "duration": dt, "median": med}
                    )
            durations.append(dt)

            if step % self.cfg.log_every == 0:
                self.metrics_log.append(
                    {"step": step, "loss": loss, "sec_per_step": dt}
                )

            next_step = step + 1
            if self.ckpt and next_step % self.ckpt.cfg.every_steps == 0:
                self.ckpt.save(state, next_step,
                               extra={"data_state": {"step": next_step}})
            if self._preempted:
                if self.ckpt:
                    self.ckpt.save(state, next_step,
                                   extra={"data_state": {"step": next_step},
                                          "preempted": True}, wait=True)
                return state, next_step

        if self.ckpt:
            self.ckpt.save(state, self.cfg.total_steps,
                           extra={"data_state": {"step": self.cfg.total_steps}},
                           wait=True)
            self.ckpt.wait()
        return state, self.cfg.total_steps

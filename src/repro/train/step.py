"""train_step / serve_step builders — the functions pjit lowers at scale."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.optim import (
    AdamWConfig,
    GradCompressionConfig,
    adamw_update,
    compress_grads,
    cosine_schedule,
)
from repro.train.state import TrainState


def make_train_step(
    model: Model,
    opt_cfg: AdamWConfig | None = None,
    cc: GradCompressionConfig | None = None,
    total_steps: int = 100000,
) -> Callable:
    """(TrainState, batch) -> (TrainState, metrics)."""
    opt_cfg = opt_cfg or AdamWConfig()
    cc = cc or GradCompressionConfig()

    def train_step(state: TrainState, batch: dict):
        loss, grads = jax.value_and_grad(model.loss)(state.params, batch)
        grads, new_err, wire_bytes = compress_grads(grads, state.err, cc)
        lr_scale = cosine_schedule(
            state.opt.step, warmup=max(total_steps // 20, 1), total=total_steps
        )
        new_params, new_opt, gnorm = adamw_update(
            opt_cfg, state.params, grads, state.opt, lr_scale
        )
        metrics = {
            "loss": loss,
            "grad_norm": gnorm,
            "lr_scale": lr_scale,
            "grad_wire_bytes": wire_bytes,
        }
        return TrainState(params=new_params, opt=new_opt, err=new_err), metrics

    return train_step


def make_prefill_step(model: Model) -> Callable:
    """(params, batch) -> logits — inference prefill."""

    def prefill_step(params, batch):
        return model.forward(params, batch)

    return prefill_step


def make_serve_step(model: Model) -> Callable:
    """(params, cache, batch) -> (next_tokens, cache) — one decode step."""

    def serve_step(params, cache, batch):
        logits, cache = model.decode(params, cache, batch)
        next_tokens = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tokens[:, None], cache

    return serve_step


def supports_fused_prefill(model: Model) -> bool:
    """True if the family primes its cache with ONE full-sequence forward
    (attention-only stacks).  Recurrent families (ssm/hybrid) and
    cross-attending ones (vlm/encdec) keep the scanned per-token path."""
    return model.cfg.family in ("dense", "moe") and not model.cfg.cross_every


def make_cache_prefill_step(model: Model) -> Callable:
    """(params, cache, tokens (B, S), lengths (B,)) -> (cache, last_logits).

    Attention families take the ONE-DISPATCH path: the whole left-padded
    prompt runs through a single causal-masked forward
    (:func:`repro.models.transformer.lm_prefill`), streaming every packed
    weight once per prompt, with left-pad positions masked out of the KV
    cache so batch mates cannot pollute each other.  Other families fall
    back to one jitted lax.scan over positions (still a single device
    program, but weights stream once per token; ``lengths`` is unused
    there — recurrent state offers no post-hoc pad masking)."""
    if supports_fused_prefill(model):
        from repro.models import transformer

        def prefill_step(params, cache, tokens, lengths, tiers=None,
                         demand=None):
            return transformer.lm_prefill(params, model.cfg, cache, tokens,
                                          lengths, tiers=tiers, demand=demand)

        return prefill_step

    def prefill_step(params, cache, tokens, lengths, tiers=None, demand=None):
        del lengths  # per-token scan: no pad isolation for recurrent state
        if tiers is not None or demand is not None:
            raise ValueError(
                f"per-slot quality tiers need the fused attention prefill; "
                f"family {model.cfg.family!r} serves one tier per engine"
            )

        def body(cache, tok):  # tok (B, 1)
            logits, cache = model.decode(params, cache, {"tokens": tok})
            return cache, logits[:, -1, :]

        cache, logits = jax.lax.scan(
            body, cache, jnp.moveaxis(tokens, 1, 0)[:, :, None]
        )
        return cache, logits[-1]

    return prefill_step


def make_admit_step(model: Model) -> Callable:
    """(params, zero_cache (batch-1), live_cache, toks (1, P), lens (1,),
    slot (), tier (1,), demand (static int)) -> (live_cache, first_token ()).

    One jitted dispatch per continuous-batching admission: single-slot
    prefill on the zeroed batch-1 cache — at the request's OWN quality
    tier (``tier`` indexes each packed weight's tier-drop vector) — lane
    insert into the live cache, and the request's first greedy token
    argmaxed ON DEVICE: the host syncs on one int32, never on a
    (vocab,)-sized logits row.  ``demand`` is the static plane-demand
    floor for the prefill (the request's own tier index): plane-major
    packed weights stream only the demanded planes.  Jit it with
    ``static_argnums=(7,)`` — one trace per distinct demand, bounded by
    the tier count."""
    prefill = make_cache_prefill_step(model)

    def admit(params, zero_cache, live_cache, toks, lens, slot, tier,
              demand=0):
        one_cache, logits = prefill(params, zero_cache, toks, lens, tier,
                                    demand)
        cache = model.cache_insert_slot(live_cache, one_cache, slot)
        first = jnp.argmax(logits[0]).astype(jnp.int32)
        return cache, first

    return admit


def make_cont_decode_step(model: Model) -> Callable:
    """(params, cache, cur (B,1), active (B,) int32, tiers (B,) int32) ->
    (next (B,), cache).

    One greedy decode iteration over ALL slots of a continuous-batching
    engine, at a fixed batch width: ``active`` marks the live (DECODING)
    lanes.  Inactive lanes run the same fixed-shape program — dead lanes,
    not shape changes, so admissions and evictions never retrace — but
    their per-slot cache ``pos`` does not advance and their emitted token
    is held at ``cur``, so a FREE/DONE slot is bit-frozen until the
    scheduler re-admits it via a single-slot prefill insert.  ``tiers``
    dials each slot's quality inside the ONE dispatch: packed weights
    apply per-row plane masks, so a mixed-tier batch decodes every lane
    at its own tier with no retrace across tier changes.  (Dense lanes
    are fully isolated; MoE dead lanes are masked out of expert-capacity
    competition by ``active``, so only LIVE batch mates couple.)

    ``demand`` (static python int, default 0) is the batch plane-demand
    floor — the min live tier index the scheduler computes each tick.
    Plane-major packed weights stream only the planes that tier keeps, so
    a lo-heavy batch reads a fraction of the weight bytes.  Jit with
    ``static_argnums=(5,)``: distinct demands retrace once each, bounded
    by the tier count (not 2^planes)."""

    def cont_step(params, cache, cur, active, tiers, demand=0):
        logits, cache = model.decode(
            params, cache,
            {"tokens": cur, "active": active, "tiers": tiers,
             "demand": demand},
        )
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        nxt = jnp.where(active > 0, nxt, cur[:, 0])
        return nxt, cache

    return cont_step


def make_verify_step(model: Model) -> Callable:
    """(params, cache, window (B, W), start (B,), wlen (B,), spec (B,),
    tiers (B,), demand (static int)) -> (tokens (B, W), accepted (B,),
    cache).

    The verify half of self-speculative decoding, acceptance computed ON
    DEVICE so the host syncs on (B, W) int32 tokens plus a (B,) count —
    never on logits.  Each speculating lane's ``window`` holds its last
    emitted token followed by the k tokens the draft-tier ticks proposed;
    one batched forward at the lane's VERIFY tier scores every window
    position, overwriting the cache's draft-tier KV in place.  Row j of
    ``tokens`` is the verify tier's greedy choice after window position j,
    and ``accepted`` is the longest prefix of drafts that match it — the
    lane emits ``tokens[:accepted + 1]`` (accepted drafts plus the bonus
    token the verify pass computed for free), all exactly what plain
    verify-tier decode would have produced.

    KV rollback is one data change: rejected entries are never erased,
    the per-slot cache ``pos`` is simply set to ``start + accepted + 1``
    so later attention masks them until they are overwritten.  Lanes with
    ``wlen == 0`` (not speculating this round) pass through untouched.
    Jit with ``static_argnums=(7,)``: one trace per (demand, W) pair —
    demand is bounded by the tier count, W by the configured draft k."""

    def verify(params, cache, window, start, wlen, spec, tiers, demand=0):
        logits, cache = model.verify(
            params, cache,
            {"tokens": window, "start": start, "wlen": wlen, "spec": spec,
             "tiers": tiers, "demand": demand},
        )
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B, W)
        w = window.shape[1]
        # draft i+1 is accepted iff it matches the verify-tier choice at
        # window position i and every earlier draft was accepted too
        eq = (toks[:, : w - 1] == window[:, 1:]) \
            & (jnp.arange(w - 1, dtype=jnp.int32)[None, :]
               < (wlen - 1)[:, None])
        accepted = jnp.sum(jnp.cumprod(eq.astype(jnp.int32), axis=1), axis=1)
        pos = jnp.where(spec[None, :] > 0,
                        start[None, :] + accepted[None, :] + 1,
                        cache.kv.pos)
        cache = cache._replace(kv=cache.kv._replace(pos=pos))
        return toks, accepted, cache

    return verify


def make_decode_loop(model: Model) -> Callable:
    """(params, cache, first (B,1), xs (T,)) -> (tokens (T, B), cache).

    Greedy multi-token decode as one jitted lax.scan: T = len(xs) steps run
    device-side back to back; the host syncs once, on the returned token
    block.  ``first`` is the token sampled from the prefill logits; the
    emitted row t is the token fed at step t (so row 0 == first)."""

    def decode_loop(params, cache, first, xs):
        def body(carry, _):
            cur, cache = carry
            logits, cache = model.decode(params, cache, {"tokens": cur})
            nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
            return (nxt, cache), cur[:, 0]

        (_, cache), toks = jax.lax.scan(body, (first, cache), xs)
        return toks, cache

    return decode_loop


def make_sample_decode_loop(model: Model) -> Callable:
    """(params, cache, first (B,1), keys (T,key), temperature) ->
    (tokens (T, B), cache).

    Temperature-sampled sibling of :func:`make_decode_loop`: one PRNG key
    per step is scanned in, each next token drawn from
    ``softmax(logits / temperature)``.  Still one device program and one
    host sync per generate() call."""

    def decode_loop(params, cache, first, keys, temperature):
        def body(carry, key):
            cur, cache = carry
            logits, cache = model.decode(params, cache, {"tokens": cur})
            nxt = jax.random.categorical(
                key, logits[:, -1, :] / temperature, axis=-1
            ).astype(jnp.int32)[:, None]
            return (nxt, cache), cur[:, 0]

        (_, cache), toks = jax.lax.scan(body, (first, cache), keys)
        return toks, cache

    return decode_loop

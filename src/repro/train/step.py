"""train_step / serve_step builders — the functions pjit lowers at scale."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.optim import (
    AdamWConfig, GradCompressionConfig, adamw_update, compress_grads,
    cosine_schedule,
)
from repro.train.state import TrainState


def make_train_step(
    model: Model,
    opt_cfg: AdamWConfig | None = None,
    cc: GradCompressionConfig | None = None,
    total_steps: int = 100000,
) -> Callable:
    """(TrainState, batch) -> (TrainState, metrics)."""
    opt_cfg = opt_cfg or AdamWConfig()
    cc = cc or GradCompressionConfig()

    def train_step(state: TrainState, batch: dict):
        loss, grads = jax.value_and_grad(model.loss)(state.params, batch)
        grads, new_err, wire_bytes = compress_grads(grads, state.err, cc)
        lr_scale = cosine_schedule(
            state.opt.step, warmup=max(total_steps // 20, 1), total=total_steps
        )
        new_params, new_opt, gnorm = adamw_update(
            opt_cfg, state.params, grads, state.opt, lr_scale
        )
        metrics = {
            "loss": loss,
            "grad_norm": gnorm,
            "lr_scale": lr_scale,
            "grad_wire_bytes": wire_bytes,
        }
        return TrainState(params=new_params, opt=new_opt, err=new_err), metrics

    return train_step


def make_prefill_step(model: Model) -> Callable:
    """(params, batch) -> logits — inference prefill."""

    def prefill_step(params, batch):
        return model.forward(params, batch)

    return prefill_step


def make_serve_step(model: Model) -> Callable:
    """(params, cache, batch) -> (next_tokens, cache) — one decode step."""

    def serve_step(params, cache, batch):
        logits, cache = model.decode(params, cache, batch)
        next_tokens = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tokens[:, None], cache

    return serve_step

"""TrainState: params + AdamW moments + grad-compression error feedback."""
from __future__ import annotations

from typing import Any, NamedTuple

from repro.models.api import Model
from repro.optim import GradCompressionConfig, OptState, adamw_init_descs, compression_state_descs


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    err: Any  # error-feedback residuals (() placeholders when disabled)


def train_state_descs(model: Model, cc: GradCompressionConfig | None = None) -> TrainState:
    cc = cc or GradCompressionConfig()
    pd = model.param_descs()
    return TrainState(
        params=pd,
        opt=adamw_init_descs(pd),
        err=compression_state_descs(pd, cc),
    )

"""Fault-tolerant checkpointing with optional QSQ wire compression.

* **Atomic**: each checkpoint is written to ``step_XXXXXXXX.tmp`` and renamed
  on success; a crashed writer can never corrupt the latest checkpoint.
* **Resumable**: ``latest_step()`` + data-iterator state restore reproduce
  the exact training stream (tests kill a run mid-flight and verify bitwise
  continuation).
* **Elastic**: ``restore(..., sharding=...)`` device_puts leaves under a NEW
  NamedSharding, so a run checkpointed on one mesh restores onto another
  (scale up/down after node failure).
* **QSQ wire export**: ``export_wire`` writes the params in the paper's
  3-bit + scalar format (Table II codes, Eq. 9 scalars) — this is the
  "model sent over the channel to the edge device" artifact; ~10x smaller
  than bf16.  Training resume always uses the exact (lossless) checkpoint;
  the wire artifact is for serving/transfer.
* **Async**: ``save`` can run the serialization on a background thread so
  the step loop is not blocked (train loop overlap).
"""
from __future__ import annotations

import dataclasses
import json
import threading
from pathlib import Path

import jax
import numpy as np

from repro.core.policy import QuantPolicy
from repro.quant import pack_pytree_wire, quantize_pytree


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    directory: str
    keep_last: int = 3
    every_steps: int = 100
    async_save: bool = True


def save_pytree(tree, path: Path):
    """Atomic single-file save (npz + json treedef via key order)."""
    from repro.quant.artifact import atomic_savez, flatten_keystr

    atomic_savez(flatten_keystr(tree), Path(path))


def load_pytree(tree_like, path: Path, sharding=None):
    """Load into the structure of ``tree_like`` (descs/abstract/real arrays).

    ``sharding``: optional pytree (matching tree_like) of NamedSharding to
    device_put each leaf under — the elastic-restore path.
    """
    data = np.load(Path(path), allow_pickle=False)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    shard_flat = None
    if sharding is not None:
        shard_flat = jax.tree_util.tree_flatten(sharding)[0]
    for i, (pth, _leaf) in enumerate(flat):
        key = jax.tree_util.keystr(pth)
        arr = data[key]
        if shard_flat is not None:
            arr = jax.device_put(arr, shard_flat[i])
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        self.dir = Path(cfg.directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._thread: threading.Thread | None = None

    # -- bookkeeping ------------------------------------------------------
    def step_path(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}.npz"

    def meta_path(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}.meta.json"

    def all_steps(self) -> list[int]:
        return sorted(
            int(p.stem.split("_")[1]) for p in self.dir.glob("step_*.npz")
            if ".tmp" not in p.name
        )

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -- save / restore ---------------------------------------------------
    def _save_sync(self, state, step: int, extra: dict):
        save_pytree(state, self.step_path(step))
        meta = {"step": step, **extra}
        mp = self.meta_path(step)
        tmp = mp.with_suffix(".tmp")
        tmp.write_text(json.dumps(meta, indent=2))
        tmp.rename(mp)
        self._gc()

    def save(self, state, step: int, extra: dict | None = None, wait: bool = False):
        """Checkpoint the train state (optionally async)."""
        extra = extra or {}
        if self._thread is not None:
            self._thread.join()  # one in-flight save at a time
            self._thread = None
        # device_get NOW so the async thread sees a consistent snapshot
        snapshot = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), state
        )
        if self.cfg.async_save and not wait:
            self._thread = threading.Thread(
                target=self._save_sync, args=(snapshot, step, extra), daemon=True
            )
            self._thread.start()
        else:
            self._save_sync(snapshot, step, extra)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore(self, tree_like, step: int | None = None, sharding=None):
        """Returns (state, meta) or (None, None) when no checkpoint exists."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        state = load_pytree(tree_like, self.step_path(step), sharding=sharding)
        meta = json.loads(self.meta_path(step).read_text())
        return state, meta

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.cfg.keep_last]:
            self.step_path(s).unlink(missing_ok=True)
            self.meta_path(s).unlink(missing_ok=True)

    # -- QSQ wire export / import (the paper's channel artifact) -----------
    # Both are thin delegates over the EdgeArtifact npz codec
    # (repro.quant.artifact) — one file format for checkpoint export and the
    # quality-dial facade; artifacts written by EdgeArtifact.save load here
    # and vice versa (the artifact just carries extra tier/arch metadata).
    def export_wire(self, params, policy: QuantPolicy, name: str = "wire",
                    descs=None) -> Path:
        """Write the 3-bit+scalar encoded model; returns the file path.

        Pass the model's ``descs`` (ParamDesc tree) to group matmul weights
        along their contraction axis — the layout the quality-dial engines
        serve packed, without dequantizing."""
        from repro.quant.artifact import save_wire_npz

        qp = quantize_pytree(params, policy, descs)
        return save_wire_npz(pack_pytree_wire(qp), self.dir / f"{name}.npz")

    def load_wire(self, name_or_path: str | Path = "wire"):
        """Inverse of :func:`export_wire`: npz -> nested wire tree (lossless).

        The result feeds ``EdgeArtifact`` / ``quant.tree_from_wire``
        directly; codes and scales round-trip bit-exactly."""
        from repro.quant.artifact import load_wire_npz

        path = Path(name_or_path)
        if not path.suffix:
            path = path.with_suffix(".npz")
        if len(path.parts) == 1:  # bare name -> this manager's directory
            path = self.dir / path
        wire, _ = load_wire_npz(path)
        return wire

from repro.checkpoint.manager import (
    CheckpointConfig, CheckpointManager, save_pytree, load_pytree,
)

__all__ = ["CheckpointConfig", "CheckpointManager", "save_pytree", "load_pytree"]

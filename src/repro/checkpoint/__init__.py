from repro.checkpoint.manager import CheckpointConfig, CheckpointManager, load_pytree, save_pytree

__all__ = ["CheckpointConfig", "CheckpointManager", "save_pytree", "load_pytree"]

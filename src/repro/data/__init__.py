from repro.data.pipeline import (
    LMDataConfig, lm_batch_iterator, synthetic_image_dataset, DataIteratorState,
)

__all__ = ["LMDataConfig", "lm_batch_iterator", "synthetic_image_dataset",
           "DataIteratorState"]

from repro.data.pipeline import (
    DataIteratorState,
    LMDataConfig,
    lm_batch_iterator,
    synthetic_image_dataset,
)

__all__ = ["LMDataConfig", "lm_batch_iterator", "synthetic_image_dataset",
           "DataIteratorState"]

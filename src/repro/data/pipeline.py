"""Deterministic synthetic data pipelines with checkpointable iterator state.

No datasets ship in this container (DESIGN.md §8), so:

* **LM stream**: a deterministic PRNG token stream with learnable structure —
  a fixed random bigram transition table (peaked distribution), so a real LM
  reduces loss well below uniform entropy and e2e training is meaningful.
* **Image classification**: class-conditional Gaussian-blob images with a
  fixed random class template + noise; LeNet/ConvNet reach >95% on it,
  letting the paper's Table III / Fig. 7-8 methodology (accuracy before /
  after QSQ, per-layer sensitivity) run faithfully.

Iterator state is a (step,) counter — restoring it resumes the exact stream
(fault-tolerance requirement: data order is reproducible across restarts).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class DataIteratorState(NamedTuple):
    step: int
    seed: int


@dataclasses.dataclass(frozen=True)
class LMDataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 8  # out-degree of the bigram graph (peakedness)


def _bigram_table(vocab: int, branching: int, seed: int) -> np.ndarray:
    """Each token has `branching` likely successors (deterministic)."""
    rng = np.random.RandomState(seed)
    return rng.randint(0, vocab, size=(vocab, branching)).astype(np.int32)


def lm_batch(cfg: LMDataConfig, step: int) -> dict:
    """Batch for a given step — pure function of (cfg, step)."""
    table = _bigram_table(cfg.vocab, cfg.branching, cfg.seed)
    key = jax.random.PRNGKey(cfg.seed * 1_000_003 + step)
    k1, k2 = jax.random.split(key)
    b, s = cfg.global_batch, cfg.seq_len
    starts = jax.random.randint(k1, (b,), 0, cfg.vocab)
    choices = jax.random.randint(k2, (b, s), 0, cfg.branching)

    tbl = jnp.asarray(table)

    def walk(tok, choice):
        return tbl[tok, choice], tok

    def row(start, ch):
        _, toks = jax.lax.scan(walk, start, ch)
        return toks

    seq = jax.vmap(row)(starts, choices)  # (b, s)
    labels = jnp.concatenate([seq[:, 1:], seq[:, :1]], axis=1)
    return {"tokens": seq, "labels": labels}


def lm_batch_iterator(
    cfg: LMDataConfig, state: DataIteratorState | None = None
) -> Iterator[tuple[DataIteratorState, dict]]:
    """Yields (state_after, batch); resuming from a saved state replays the
    identical stream."""
    step = state.step if state else 0
    while True:
        batch = lm_batch(cfg, step)
        step += 1
        yield DataIteratorState(step=step, seed=cfg.seed), batch


def synthetic_image_dataset(
    n: int, hw: tuple, channels: int, n_classes: int, seed: int = 0,
    noise: float = 0.35,
) -> tuple[np.ndarray, np.ndarray]:
    """Class-template images + noise: (images (N,H,W,C) f32 in [0,1], labels)."""
    rng = np.random.RandomState(seed)
    h, w = hw
    templates = rng.rand(n_classes, h, w, channels).astype(np.float32)
    # smooth the templates a little so convs have local structure to find
    for _ in range(2):
        templates = 0.25 * (
            np.roll(templates, 1, 1) + np.roll(templates, -1, 1)
            + np.roll(templates, 1, 2) + np.roll(templates, -1, 2)
        )
    labels = rng.randint(0, n_classes, size=n).astype(np.int32)
    images = templates[labels] + noise * rng.randn(n, h, w, channels).astype(np.float32)
    return np.clip(images, 0.0, 1.0), labels


def image_batches(images, labels, batch: int, seed: int = 0, start_step: int = 0):
    """Infinite shuffled batch iterator with reproducible order."""
    n = images.shape[0]
    step = start_step
    while True:
        rng = np.random.RandomState(seed + step)
        idx = rng.randint(0, n, size=batch)
        yield step, {"images": jnp.asarray(images[idx]),
                     "labels": jnp.asarray(labels[idx])}
        step += 1

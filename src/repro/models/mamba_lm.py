"""Pure Mamba2 LM (attention-free): embed -> N x (norm + SSD mixer) -> head."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.base import map_stacked, xscan


def _ssm_cfg(cfg: ArchConfig) -> S.SSMConfig:
    d_inner = 2 * cfg.d_model
    return S.SSMConfig(
        d_model=cfg.d_model,
        d_inner=d_inner,
        n_heads=d_inner // cfg.ssm_head_dim,
        head_dim=cfg.ssm_head_dim,
        state=cfg.ssm_state,
        n_groups=cfg.ssm_groups,
        chunk=cfg.ssm_chunk,
    )


def mamba_descs(cfg: ArchConfig) -> dict:
    sc = _ssm_cfg(cfg)
    block = {"ln": L.rmsnorm_desc(cfg.d_model), "mixer": S.ssm_descs(sc, dtype=cfg.dtype)}
    return {
        "embed": L.embed_descs(cfg.vocab, cfg.d_model, dtype=cfg.dtype),
        "final_norm": L.rmsnorm_desc(cfg.d_model),
        "blocks": map_stacked(cfg.n_layers, block),
    }


def mamba_forward(params: dict, cfg: ArchConfig, tokens: jax.Array):
    sc = _ssm_cfg(cfg)
    x = L.embed(params["embed"], tokens, cfg.dtype)

    def body(x, bp):
        return x + S.ssm_forward(bp["mixer"], L.rmsnorm(x, bp["ln"]), sc), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = xscan(body_fn, x, params["blocks"])
    x = L.rmsnorm(x, params["final_norm"])
    return L.lm_head(params["embed"], x), jnp.float32(0.0)


def mamba_loss(params: dict, cfg: ArchConfig, batch: dict) -> jax.Array:
    logits, _ = mamba_forward(params, cfg, batch["tokens"])
    return L.next_token_loss(logits, batch["labels"])


class MambaCache(NamedTuple):
    ssm: Any  # SSMState stacked (L, ...)


def mamba_cache_descs(cfg: ArchConfig, batch: int, cache_len: int) -> MambaCache:
    sc = _ssm_cfg(cfg)
    return MambaCache(ssm=map_stacked(cfg.n_layers, S.ssm_state_descs(sc, batch, cfg.dtype)))


def mamba_decode(params: dict, cfg: ArchConfig, cache: MambaCache, tokens: jax.Array):
    sc = _ssm_cfg(cfg)
    x = L.embed(params["embed"], tokens, cfg.dtype)

    def body(x, inp):
        bp, st = inp
        h, st2 = S.ssm_decode(bp["mixer"], L.rmsnorm(x, bp["ln"]), st, sc)
        return x + h, st2

    x, new_ssm = xscan(body, x, (params["blocks"], cache.ssm))
    x = L.rmsnorm(x, params["final_norm"])
    return L.lm_head(params["embed"], x), MambaCache(ssm=new_ssm)

"""Shared neural-net layers: RMSNorm, RoPE, GQA attention (full / windowed /
chunked / decode), SwiGLU MLP, MoE with capacity routing, embeddings.

Pure functions over explicit param dicts; no framework objects.  Attention
keeps heads as a separate tensor dim so the "heads" logical axis shards
cleanly over the mesh "model" axis.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import ParamDesc, constrain, dense, xscan
from repro.quant.store import is_store

# --------------------------------------------------------------------------
# WeightStore view.
#
# Serving can ship weights as WeightStore leaves (quant/store.py: QSQ
# levels or 3-bit bit-planes + scales) instead of dense arrays — the
# paper's decode-on-use.  W() is the shift-and-scale decoder (Table II)
# applied where the weight is consumed; because params flow through the
# layer scan as xs, only ONE layer's dense weights ever materialize at a
# time, while the step *arguments* (= HBM residency) stay at ~3.2-5
# bits/weight.  matvec() goes one step further for 1-axis contractions:
# packed leaves route through the Pallas qsq_matmul kernel
# (kernels/qsq_matmul.py), which fuses the decode into the matmul tile
# loop so dense weights never exist outside VREGs.
# --------------------------------------------------------------------------
def W(p):
    """Weight view: decode a WeightStore leaf to dense, pass arrays through."""
    if is_store(p):
        # qsqlint: disable=QSQ001 -- decode-at-consumption for non-matmul
        # leaves (norms, embeddings); matmul weights go through matvec()
        return p.as_dense()
    return p


def matvec(p, x: jax.Array, tiers: jax.Array | None = None,
           demand: int | None = None) -> jax.Array:
    """x (..., K) contracted with weight p (K, *rest) -> (..., *rest).

    WeightStore leaves dispatch their own matmul (fused dequant-matmul for
    PackedWeight); dense arrays take the plain tensordot.  Output dtype
    follows x.

    ``tiers`` (B,) int32 — per-slot quality-tier indices (continuous
    batching) — engages per-row plane masking on packed leaves that carry a
    ``tier_drops`` vector: each batch row contracts against the weight at
    ITS tier, bit-identical to serving that row from plane-truncated
    params.  Leaves without a tier vector (never truncated by any tier, or
    dense) ignore ``tiers`` entirely.

    ``demand`` (static python int) is the batch plane-demand floor — the
    minimum live tier index this tick.  Packed leaves turn it into a
    per-leaf ``demand_drop`` so the kernel only streams the planes some
    live row actually wants (see ``PackedWeight.matmul``)."""
    if is_store(p):
        if tiers is not None:
            masks = getattr(p, "tier_plane_masks", lambda: None)()
            if masks is not None:
                return p.matmul(x, plane_mask=masks[tiers],
                                demand_tier=demand)
        return p.matmul(x)
    return jnp.tensordot(x, p.astype(x.dtype), axes=1)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def rmsnorm_desc(d: int) -> ParamDesc:
    return ParamDesc((d,), (None,), init="ones")


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * scale.astype(jnp.float32)).astype(dt)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, hd), positions: (..., S) -> same shape, rotated pairs."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (np.arange(0, half, dtype=np.float32) * 2.0 / hd))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def sinusoidal_pos_emb(seq: int, d: int) -> np.ndarray:
    pos = np.arange(seq, dtype=np.float32)[:, None]
    i = np.arange(d // 2, dtype=np.float32)[None, :]
    ang = pos / np.power(10000.0, 2.0 * i / d)
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# Attention (GQA)
# --------------------------------------------------------------------------
def attn_descs(d: int, n_heads: int, n_kv: int, head_dim: int,
               qk_norm: bool = False, dtype=jnp.float32) -> dict:
    descs = {
        "wq": ParamDesc((d, n_heads, head_dim), ("embed", "heads", None), dtype=dtype),
        "wk": ParamDesc((d, n_kv, head_dim), ("embed", "kv_heads", None), dtype=dtype),
        "wv": ParamDesc((d, n_kv, head_dim), ("embed", "kv_heads", None), dtype=dtype),
        "wo": ParamDesc((n_heads, head_dim, d), ("heads", None, "embed"), dtype=dtype),
    }
    if qk_norm:
        descs["q_norm"] = rmsnorm_desc(head_dim)
        descs["k_norm"] = rmsnorm_desc(head_dim)
    return descs


def _project_qkv(p: dict, x: jax.Array, positions, theta: float,
                 tiers: jax.Array | None = None, demand: int | None = None):
    q = matvec(p["wq"], x, tiers, demand)  # (b, s, h, hd)
    k = matvec(p["wk"], x, tiers, demand)
    v = matvec(p["wv"], x, tiers, demand)
    if "q_norm" in p:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    if positions is not None:
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    q = constrain(q, ("batch", "seq_act", "heads", None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    v = constrain(v, ("batch", None, "kv_heads", None))
    return q, k, v


def _gqa_scores_apply(q, k, v, mask):
    """q (B,S,H,hd), k/v (B,T,Kv,hd), mask (B,1,1,S,T) or broadcastable."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k) / np.sqrt(hd)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    probs = constrain(probs, ("batch", "kv_heads", None, None, None))
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(b, s, h, hd)


def causal_mask(s: int, t: int, offset: int = 0, window: int | None = None):
    """(s, t) boolean mask; query i (global pos offset+i) sees key j iff
    j <= offset+i and (no window or offset+i - j < window)."""
    qi = offset + jnp.arange(s)[:, None]
    kj = jnp.arange(t)[None, :]
    m = kj <= qi
    if window is not None:
        m = m & (qi - kj < window)
    return m


def attention(
    p: dict,
    x: jax.Array,
    *,
    positions: jax.Array | None = None,
    theta: float = 10000.0,
    window: int | None = None,
    q_chunk: int = 2048,
    causal: bool = True,
) -> jax.Array:
    """Full-sequence (training / prefill) GQA attention.

    Long sequences are processed in q-chunks (scan) so the score matrix never
    exceeds (chunk x T) — with a sliding window the kv view per chunk is also
    sliced to (window + chunk), making SWA genuinely sub-quadratic.
    """
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, positions, theta)

    if s <= q_chunk:
        mask = causal_mask(s, s, window=window) if causal else jnp.ones((s, s), bool)
        out = _gqa_scores_apply(q, k, v, mask[None, None, None])
    elif window is not None and window + q_chunk < s:
        # Sliding-window: pad k/v by `window` on the left, slice a
        # (window + chunk) kv view per q-chunk.
        pad = ((0, 0), (window, 0), (0, 0), (0, 0))
        kp, vp = jnp.pad(k, pad), jnp.pad(v, pad)
        n_chunks = s // q_chunk
        qc = q.reshape(b, n_chunks, q_chunk, *q.shape[2:])

        def body(_, i):
            qi = qc[:, i]
            start = i * q_chunk  # global index of first query in the chunk
            kv_len = window + q_chunk
            ks = jax.lax.dynamic_slice_in_dim(kp, start, kv_len, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(vp, start, kv_len, axis=1)
            # key j in the slice has global position start - window + j
            qpos = start + jnp.arange(q_chunk)[:, None]
            kpos = start - window + jnp.arange(kv_len)[None, :]
            m = (kpos <= qpos) & (qpos - kpos < window) & (kpos >= 0)
            return None, _gqa_scores_apply(qi, ks, vs, m[None, None, None])

        _, outs = xscan(body, None, jnp.arange(n_chunks))
        out = jnp.moveaxis(outs, 0, 1).reshape(b, s, *q.shape[2:])
    else:
        n_chunks = s // q_chunk
        qc = q.reshape(b, n_chunks, q_chunk, *q.shape[2:])

        def body(_, i):
            qi = qc[:, i]
            m = causal_mask(q_chunk, s, offset=i * q_chunk, window=window)
            return None, _gqa_scores_apply(qi, k, v, m[None, None, None])

        _, outs = xscan(body, None, jnp.arange(n_chunks))
        out = jnp.moveaxis(outs, 0, 1).reshape(b, s, *q.shape[2:])

    return jnp.einsum("bshk,hkd->bsd", out, W(p["wo"]).astype(x.dtype))


class KVCache(NamedTuple):
    """Decode-time cache.  For SWA the buffers are ring buffers of length
    window; otherwise they are full-length.

    ``pos`` is PER-SLOT: each batch lane counts its own tokens, so slots
    admitted at different times (continuous batching) decode at different
    depths inside one fixed-width program.  ``pad`` is the per-slot
    left-pad count of the prompt that primed the cache: entries at cache
    index < pad[b] hold projections of pad tokens and are masked out of
    every attention (so one slot's padding can never leak into another
    prompt's logits).  RoPE positions are pad-relative (cache index -
    pad), so a prompt sees the same positions it would see served alone.
    A zero-initialized cache (pos == pad == 0) reproduces the legacy
    unpadded behaviour exactly."""

    k: jax.Array  # (B, T, Kv, hd)
    v: jax.Array
    pos: jax.Array  # (B,) int32 — tokens already in each slot's lane
    pad: jax.Array  # (B,) int32 — per-slot left-pad count (see above)


def kv_cache_descs(b: int, t: int, n_kv: int, head_dim: int, dtype) -> KVCache:
    return KVCache(
        k=ParamDesc((b, t, n_kv, head_dim), ("batch", "seq_kv", "kv_heads", None), dtype=dtype, init="zeros"),
        v=ParamDesc((b, t, n_kv, head_dim), ("batch", "seq_kv", "kv_heads", None), dtype=dtype, init="zeros"),
        pos=ParamDesc((b,), ("batch",), dtype=jnp.int32, init="zeros"),
        pad=ParamDesc((b,), ("batch",), dtype=jnp.int32, init="zeros"),
    )


def decode_attention(
    p: dict,
    x: jax.Array,
    cache: KVCache,
    *,
    theta: float = 10000.0,
    window: int | None = None,
    use_rope: bool = True,
    active: jax.Array | None = None,
    tiers: jax.Array | None = None,
    demand: int | None = None,
) -> tuple[jax.Array, KVCache]:
    """One-token decode: x (B, 1, d); cache holds T past positions.

    Each slot writes at its own ``pos[b]`` (continuous batching: lanes
    decode at independent depths).  ``active`` (B,) marks live lanes: an
    inactive (FREE / DONE) slot still flows through the fixed-width
    program — same shapes, no recompile — but its ``pos`` does not
    advance, so it is a dead lane whose writes land on a yet-unused index
    of its own (dead) lane and whose output is discarded by the caller.
    ``tiers`` (B,) selects each slot's quality tier inside the packed
    projections (per-row plane masks — see :func:`matvec`); ``demand``
    (static) is the batch plane-demand floor the kernels stream by."""
    b = x.shape[0]
    t = cache.k.shape[1]
    positions = (cache.pos - cache.pad)[:, None] if use_rope else None
    q, k_new, v_new = _project_qkv(p, x, positions, theta, tiers, demand)

    slot = cache.pos % t if window is not None else jnp.minimum(cache.pos, t - 1)
    bidx = jnp.arange(b)
    k = cache.k.at[bidx, slot].set(k_new[:, 0].astype(cache.k.dtype))
    v = cache.v.at[bidx, slot].set(v_new[:, 0].astype(cache.v.dtype))

    idx = jnp.arange(t)
    if window is not None:
        # ring buffer: valid entries are the last min(pos+1, window) writes
        age = (slot[:, None] - idx[None, :]) % t  # (B, T)
        valid = age < jnp.minimum(cache.pos + 1, t)[:, None]
        # mask surviving left-pad entries (global index of an entry = pos - age)
        valid = valid & ((cache.pos[:, None] - age) >= cache.pad[:, None])
    else:
        valid = (idx[None, :] <= cache.pos[:, None]) & (idx[None, :] >= cache.pad[:, None])
    mask = valid[:, None, None, None, :]  # (B,1,1,1,T)

    out = _gqa_scores_apply(q, k.astype(q.dtype), v.astype(q.dtype), mask)
    y = jnp.einsum("bshk,hkd->bsd", out, W(p["wo"]).astype(x.dtype))
    step = jnp.ones((b,), jnp.int32) if active is None else active.astype(jnp.int32)
    return y, KVCache(k=k, v=v, pos=cache.pos + step, pad=cache.pad)


def prefill_attention(
    p: dict,
    x: jax.Array,
    cache: KVCache,
    *,
    positions: jax.Array,  # (B, S) pad-relative positions
    pad: jax.Array,  # (B,) per-slot left-pad count
    theta: float = 10000.0,
    window: int | None = None,
    tiers: jax.Array | None = None,
    demand: int | None = None,
) -> tuple[jax.Array, KVCache]:
    """Full-sequence cache prefill: x (B, S, d) over the whole left-padded
    prompt in ONE dispatch (vs one decode_attention call per token).

    Causal + left-pad masked attention over the prompt, then the projected
    k/v land in cache slots [0, S) (ring-wrapped for SWA).  Pad positions
    are masked as keys everywhere, so they cannot pollute shorter prompts;
    their own (garbage) outputs only feed their own masked positions.
    ``tiers`` (B,) primes each slot's cache at its own quality tier (the
    masks broadcast over the sequence dim).  Returns (y (B, S, d), primed
    cache with per-slot pos = S, pad recorded)."""
    b, s, _ = x.shape
    t = cache.k.shape[1]
    q, k_new, v_new = _project_qkv(p, x, positions, theta, tiers, demand)

    kj = jnp.arange(s)[None, None, :]
    mask = causal_mask(s, s, window=window)[None] & (kj >= pad[:, None, None])
    out = _gqa_scores_apply(q, k_new, v_new, mask[:, None, None])
    y = jnp.einsum("bshk,hkd->bsd", out, W(p["wo"]).astype(x.dtype))

    if s <= t:
        k = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k_new.astype(cache.k.dtype), 0, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v_new.astype(cache.v.dtype), 0, axis=1)
    else:
        # SWA ring with prompt longer than the window: keep the last t
        # tokens at their ring slots (global index i lives at i % t).
        keep = jnp.arange(s - t, s)
        slots = keep % t
        k = cache.k.at[:, slots].set(k_new[:, keep].astype(cache.k.dtype))
        v = cache.v.at[:, slots].set(v_new[:, keep].astype(cache.v.dtype))
    return y, KVCache(k=k, v=v, pos=jnp.full((b,), s, jnp.int32), pad=pad)


def verify_attention(
    p: dict,
    x: jax.Array,
    cache: KVCache,
    *,
    start: jax.Array,  # (B,) int32 — first cache index of each slot's window
    wlen: jax.Array,   # (B,) int32 — window tokens per slot (0 = not verifying)
    theta: float = 10000.0,
    use_rope: bool = True,
    tiers: jax.Array | None = None,
    demand: int | None = None,
) -> tuple[jax.Array, KVCache]:
    """Multi-position decode for self-speculative VERIFY: x (B, W, d) is a
    per-slot window of already-chosen tokens (the last emitted token plus
    the drafted continuation) fed at cache indices ``start + j``.

    The window's k/v OVERWRITE cache entries ``[start, start+wlen)`` per
    slot — replacing the draft-tier KV the draft ticks left there with
    this dispatch's (verify-tier) projections — before attention runs, so
    window query j attends causally over exactly the entries a sequential
    decode of token j would see: the prefix ``[pad, start)`` plus the
    window's own writes up to j.  Entries at index > ``start + j`` (stale
    drafts from deeper draft ticks) are masked, never attended.  Lanes
    with ``wlen == 0`` are dead: nothing written, ``pos`` unchanged,
    output garbage the caller discards.

    ``pos`` on written lanes is set to ``start + wlen`` (as if every
    draft were accepted); the caller rolls it back to the accepted prefix
    after the acceptance compare — a data change on the per-slot ``pos``
    leaf, which is all the KV rollback there is.  Full-length caches
    only: the SWA ring buffer's wrap arithmetic is not supported here
    (the engine refuses speculation for windowed configs)."""
    b, w, _ = x.shape
    t = cache.k.shape[1]
    positions = None
    if use_rope:
        positions = start[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :] \
            - cache.pad[:, None]
    q, k_new, v_new = _project_qkv(p, x, positions, theta, tiers, demand)

    # scatter-free window write: entry idx of lane b takes window slot
    # idx - start[b] when that slot exists, else keeps its cached value
    idx = jnp.arange(t, dtype=jnp.int32)[None, :]  # (1, T)
    rel = idx - start[:, None]                     # (B, T)
    inwin = (rel >= 0) & (rel < wlen[:, None])     # (B, T)
    relc = jnp.clip(rel, 0, w - 1)[:, :, None, None]
    k = jnp.where(inwin[:, :, None, None],
                  jnp.take_along_axis(k_new.astype(cache.k.dtype), relc, axis=1),
                  cache.k)
    v = jnp.where(inwin[:, :, None, None],
                  jnp.take_along_axis(v_new.astype(cache.v.dtype), relc, axis=1),
                  cache.v)

    # window query j (global index start + j) sees pad <= idx <= start + j
    qpos = start[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]  # (B, W)
    valid = (idx[:, None, :] <= qpos[:, :, None]) \
        & (idx[:, None, :] >= cache.pad[:, None, None])              # (B, W, T)
    mask = valid[:, None, None, :, :]  # (B,1,1,W,T)

    out = _gqa_scores_apply(q, k.astype(q.dtype), v.astype(q.dtype), mask)
    y = jnp.einsum("bshk,hkd->bsd", out, W(p["wo"]).astype(x.dtype))
    pos = jnp.where(wlen > 0, start + wlen, cache.pos)
    return y, KVCache(k=k, v=v, pos=pos, pad=cache.pad)


def cross_attention(p: dict, x: jax.Array, kv: tuple[jax.Array, jax.Array]) -> jax.Array:
    """Cross-attn with precomputed encoder/vision K, V: kv = (k, v) (B,T,Kv,hd)."""
    q = matvec(p["wq"], x)
    if "q_norm" in p:
        q = rmsnorm(q, p["q_norm"])
    k, v = kv
    t = k.shape[1]
    mask = jnp.ones((1, 1, 1, 1, t), bool)
    out = _gqa_scores_apply(q, k.astype(q.dtype), v.astype(q.dtype), mask)
    return jnp.einsum("bshk,hkd->bsd", out, W(p["wo"]).astype(x.dtype))


def cross_kv(p: dict, enc: jax.Array) -> tuple[jax.Array, jax.Array]:
    k = matvec(p["wk"], enc)
    v = matvec(p["wv"], enc)
    if "k_norm" in p:
        k = rmsnorm(k, p["k_norm"])
    return k, v


# --------------------------------------------------------------------------
# MLP (SwiGLU)
# --------------------------------------------------------------------------
def mlp_descs(d: int, ff: int, dtype=jnp.float32) -> dict:
    return {
        "wg": dense(d, ff, "embed", "mlp", dtype=dtype),
        "wu": dense(d, ff, "embed", "mlp", dtype=dtype),
        "wd": dense(ff, d, "mlp", "embed", dtype=dtype),
    }


def mlp(p: dict, x: jax.Array, tiers: jax.Array | None = None,
        demand: int | None = None) -> jax.Array:
    g = jax.nn.silu(matvec(p["wg"], x, tiers, demand))
    u = matvec(p["wu"], x, tiers, demand)
    g = constrain(g, ("batch", "seq_act", "mlp"))
    return constrain(matvec(p["wd"], g * u, tiers, demand),
                     ("batch", "seq_act", None))


# --------------------------------------------------------------------------
# MoE with capacity routing (scatter/gather — compute-faithful FLOPs)
# --------------------------------------------------------------------------
def moe_descs(d: int, ff: int, n_experts: int, dtype=jnp.float32) -> dict:
    return {
        "router": dense(d, n_experts, "embed", None, dtype=jnp.float32, init="small"),
        "wg": ParamDesc((n_experts, d, ff), ("experts", "embed", "mlp"), dtype=dtype),
        "wu": ParamDesc((n_experts, d, ff), ("experts", "embed", "mlp"), dtype=dtype),
        "wd": ParamDesc((n_experts, ff, d), ("experts", "mlp", "embed"), dtype=dtype),
    }


def moe(
    p: dict,
    x: jax.Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    active: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Top-k token-choice MoE with SHARD-LOCAL capacity routing.

    Returns (y, aux_loss).  Tokens are grouped by data-parallel shard
    (leading axis sharded over the dp mesh axes); the position-in-expert
    cumsum and the capacity-buffer scatter/gather then never cross a dp
    boundary — only the expert FFN einsum communicates (over the expert/
    model axis), which is the real MoE all-to-all.  With no mesh installed
    (CPU tests) shards == 1 and this is plain global capacity routing.

    Dispatch is scatter/gather (not one-hot einsum) so HLO FLOPs match the
    true expert compute: per shard, E buffers of C = ceil(T_local * k * cf
    / E) tokens, batched-matmul'd through their expert FFN.  Overflowing
    tokens are dropped (capacity routing); dropped slots contribute zero.

    ``active`` (B,) int32/bool marks live batch lanes (continuous
    batching).  A dead (FREE/DONE) slot's frozen token is routed to a
    sentinel expert id ``e``: it sorts AFTER every real assignment, so it
    neither claims a capacity slot nor displaces a live token's position —
    dead lanes drop out of expert competition entirely, giving MoE decode
    the dense families' slot-history invariance.  Live batch mates still
    share capacity, exactly as a static batch would.
    """
    from repro.models.base import data_shard_count

    b, s, d = x.shape
    e = p["router"].shape[-1]
    t = b * s
    shards = data_shard_count()
    if shards <= 1 or t % shards or (t // shards) < max(top_k, 4):
        shards = 1
    tl = t // shards
    xt = constrain(x.reshape(shards, tl, d), ("batch", None, None))

    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (S, TL, E)
    topw, topi = jax.lax.top_k(probs, top_k)  # (S, TL, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    # load-balancing aux loss (Switch-style), over all tokens; top-1 counts
    # via per-shard bincount — no (tokens, E) one-hot materializes
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(
        jax.vmap(lambda t: jnp.bincount(t, length=e))(topi[..., 0]).astype(jnp.float32)
        / tl,
        axis=0,
    )
    aux = e * jnp.sum(me * ce)

    cap = int(np.ceil(tl * top_k * capacity_factor / e))

    flat_e = topi.reshape(shards, tl * top_k)  # expert id per assignment
    flat_w = topw.reshape(shards, tl * top_k)
    tok_of = jnp.repeat(jnp.arange(tl), top_k)  # (TL*k,) same for each shard

    if active is not None:
        # (B,) lane mask -> per-assignment mask in the same (shards, TL*k)
        # layout the routing tensors use
        act = jnp.broadcast_to(
            active.astype(bool)[:, None], (b, s)
        ).reshape(shards, tl)
        act_a = jnp.take(act, tok_of, axis=1)  # (S, TL*k)
        flat_e = jnp.where(act_a, flat_e, e)  # sentinel: out of competition
        flat_w = flat_w * act_a.astype(flat_w.dtype)

    # position of each assignment within its (shard-local) expert buffer,
    # via a per-shard stable sort instead of a (tokens, E) cumsum: the sort
    # runs along the UNSHARDED axis (per dp shard), so no collective, and
    # the peak intermediate is (S, TL*k) int32 instead of (S, TL*k, E).
    order = jnp.argsort(flat_e, axis=1, stable=True)
    rank = jnp.argsort(order, axis=1)  # rank of each assignment in expert-major order
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    starts = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(e), side="left")
    )(sorted_e)  # (S, E) — first sorted index of each expert
    flat_e_c = jnp.minimum(flat_e, e - 1)  # sentinel clamped for gathers
    pos = rank - jnp.take_along_axis(starts, flat_e_c, axis=1)
    keep = (pos < cap) & (flat_e < e)
    pos_c = jnp.where(keep, pos, cap)  # dropped/dead -> trash slot

    xg = xt[:, tok_of, :]  # (S, TL*k, d)
    # Two-stage dispatch: a vmapped (per-shard, batched) scatter into a
    # buffer whose expert dim is NOT yet sharded — fully shard-local, zero
    # collectives — then reshard the filled buffer onto the expert/model
    # axis.  XLA lowers the resharding as the intrinsic MoE all-to-all.
    # (Constraining the expert dim before the scatter makes SPMD fall back
    # to partial-scatter + full-buffer all-reduce; an unbatched 3-index
    # scatter makes it all-gather the 68 GB update tensor — both measured
    # on qwen3-moe via benchmarks/hillclimb.py --change moe_local.)
    buf = jnp.zeros((shards, e, cap + 1, d), xt.dtype)
    buf = constrain(buf, ("batch", None, None, None))
    buf = jax.vmap(lambda b0, ei, pi, xi: b0.at[ei, pi].add(xi))(
        buf, flat_e_c, pos_c, xg
    )
    buf = constrain(buf[:, :, :cap], ("batch", "experts", None, None))

    # expert FFN (batched over shards x experts)
    g = jax.nn.silu(jnp.einsum("secd,edf->secf", buf, W(p["wg"]).astype(buf.dtype)))
    u = jnp.einsum("secd,edf->secf", buf, W(p["wu"]).astype(buf.dtype))
    g = constrain(g, ("batch", "experts", None, "mlp"))
    yb = jnp.einsum("secf,efd->secd", g * u, W(p["wd"]).astype(buf.dtype))
    yb = constrain(yb, ("batch", "experts", None, None))

    # gather back: reshard the expert outputs off the model axis first so
    # the (vmapped, per-shard) index-gather is shard-local.
    yb = constrain(yb, ("batch", None, None, None))
    ya = jax.vmap(lambda yi, ei, pi: yi[ei, pi])(
        yb, flat_e_c, jnp.minimum(pos_c, cap - 1)
    )  # (S, TL*k, d)
    ya = ya * (flat_w * keep.astype(flat_w.dtype))[..., None].astype(ya.dtype)
    y = jnp.zeros((shards, tl, d), xt.dtype)
    y = jax.vmap(lambda y0, yi: y0.at[tok_of].add(yi))(y, ya)
    y = constrain(y, ("batch", None, None))
    return y.reshape(b, s, d), aux


# --------------------------------------------------------------------------
# Embeddings / head
# --------------------------------------------------------------------------
def embed_descs(vocab: int, d: int, dtype=jnp.float32) -> dict:
    return {
        "tok": ParamDesc((vocab, d), ("vocab", "embed"), dtype=dtype, init="normal"),
        "head": dense(d, vocab, "embed", "vocab", dtype=dtype, init="normal", scale=0.5),
    }


def embed(p: dict, tokens: jax.Array, dtype) -> jax.Array:
    x = jnp.take(W(p["tok"]), tokens, axis=0).astype(dtype)
    return constrain(x, ("batch", "seq_act", None))


def lm_head(p: dict, x: jax.Array, tiers: jax.Array | None = None,
            demand: int | None = None) -> jax.Array:
    logits = matvec(p["head"], x, tiers, demand).astype(jnp.float32)
    return constrain(logits, ("batch", "seq_act", "vocab"))


def next_token_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Masked CE that keeps the vocab dim sharded.

    logsumexp reduces over the (model-sharded) vocab axis with an implicit
    all-reduce; the label pick is a one-hot einsum (SPMD-friendly — no
    all-gather of the logits, unlike take_along_axis which XLA materializes
    replicated).  labels < 0 are masked out.
    """
    lse = jax.scipy.special.logsumexp(logits, axis=-1)  # (B, S)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    picked = jnp.einsum("bsv,bsv->bs", logits, onehot)
    ll = picked - lse
    mask = (labels >= 0).astype(jnp.float32)
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)

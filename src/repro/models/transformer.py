"""Decoder-only LM family: dense (deepseek/smollm/phi4/qwen3), MoE
(mixtral/qwen3-moe) and VLM (llama-3.2-vision, gated cross-attn blocks).

Layers are scan-stacked (params carry a leading L axis) so the HLO stays
small at 40-72 layers, and each block body is optionally rematerialized.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.base import ParamDesc, constrain, map_stacked, xscan


# --------------------------------------------------------------------------
# Descriptors
# --------------------------------------------------------------------------
def _block_descs(cfg: ArchConfig) -> dict:
    d = {
        "ln1": L.rmsnorm_desc(cfg.d_model),
        "attn": L.attn_descs(cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd,
                             qk_norm=cfg.qk_norm, dtype=cfg.dtype),
        "ln2": L.rmsnorm_desc(cfg.d_model),
    }
    if cfg.moe is not None:
        d["moe"] = L.moe_descs(cfg.d_model, cfg.d_ff, cfg.moe.n_experts, dtype=cfg.dtype)
    else:
        d["mlp"] = L.mlp_descs(cfg.d_model, cfg.d_ff, dtype=cfg.dtype)
    return d


def _cross_block_descs(cfg: ArchConfig) -> dict:
    return {
        "ln": L.rmsnorm_desc(cfg.d_model),
        "attn": L.attn_descs(cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd,
                             qk_norm=cfg.qk_norm, dtype=cfg.dtype),
        "gate": ParamDesc((1,), (None,), init="zeros"),
        "ln_mlp": L.rmsnorm_desc(cfg.d_model),
        "mlp": L.mlp_descs(cfg.d_model, cfg.d_ff, dtype=cfg.dtype),
        "gate_mlp": ParamDesc((1,), (None,), init="zeros"),
    }


def lm_descs(cfg: ArchConfig) -> dict:
    descs = {
        "embed": L.embed_descs(cfg.vocab, cfg.d_model, dtype=cfg.dtype),
        "final_norm": L.rmsnorm_desc(cfg.d_model),
        "blocks": map_stacked(cfg.n_layers, _block_descs(cfg)),
    }
    if cfg.cross_every:
        n_cross = cfg.n_layers // cfg.cross_every
        descs["cross_blocks"] = map_stacked(n_cross, _cross_block_descs(cfg))
    return descs


# --------------------------------------------------------------------------
# Forward (train / prefill)
# --------------------------------------------------------------------------
def _block_fwd(cfg: ArchConfig, p: dict, x: jax.Array, positions: jax.Array):
    h = L.attention(
        p["attn"], L.rmsnorm(x, p["ln1"]),
        positions=positions, theta=cfg.rope_theta, window=cfg.window,
    )
    x = constrain(x + h, ("batch", "seq_act", None))
    y = L.rmsnorm(x, p["ln2"])
    if cfg.moe is not None:
        f, aux = L.moe(p["moe"], y, top_k=cfg.moe.top_k,
                       capacity_factor=cfg.moe.capacity_factor)
    else:
        f, aux = L.mlp(p["mlp"], y), jnp.float32(0.0)
    return x + f, aux


def _cross_block_fwd(p: dict, x: jax.Array, kv):
    h = L.cross_attention(p["attn"], L.rmsnorm(x, p["ln"]), kv)
    x = x + jnp.tanh(p["gate"]).astype(x.dtype) * h
    f = L.mlp(p["mlp"], L.rmsnorm(x, p["ln_mlp"]))
    return x + jnp.tanh(p["gate_mlp"]).astype(x.dtype) * f


def lm_forward(
    params: dict,
    cfg: ArchConfig,
    tokens: jax.Array,  # (B, S) int32
    vision_embeds: jax.Array | None = None,  # (B, T_img, d) for vlm
) -> tuple[jax.Array, jax.Array]:
    """Returns (logits (B,S,vocab) f32, moe aux loss)."""
    b, s = tokens.shape
    x = L.embed(params["embed"], tokens, cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))

    def body(carry, bp):
        x, aux = carry
        x, a = _block_fwd(cfg, bp, x, positions)
        return (x, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body

    if not cfg.cross_every:
        (x, aux), _ = xscan(body_fn, (x, jnp.float32(0.0)), params["blocks"])
    else:
        n_cross = cfg.n_layers // cfg.cross_every
        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape(n_cross, cfg.cross_every, *a.shape[1:]),
            params["blocks"],
        )
        # precompute cross K/V once per cross block (they share the encoder)
        cross_kvs = jax.vmap(lambda cp: L.cross_kv(cp["attn"], vision_embeds))(
            params["cross_blocks"]
        )

        def group(carry, inp):
            x, aux = carry
            gblocks, cp, ckv = inp
            (x, aux), _ = xscan(body_fn, (x, aux), gblocks)
            x = _cross_block_fwd(cp, x, ckv)
            return (x, aux), None

        (x, aux), _ = xscan(
            group, (x, jnp.float32(0.0)),
            (grouped, params["cross_blocks"], cross_kvs),
        )

    x = L.rmsnorm(x, params["final_norm"])
    return L.lm_head(params["embed"], x), aux / cfg.n_layers


def lm_loss(params: dict, cfg: ArchConfig, batch: dict) -> jax.Array:
    """Next-token cross-entropy; batch = {tokens, labels[, vision_embeds]}."""
    logits, aux = lm_forward(
        params, cfg, batch["tokens"], batch.get("vision_embeds")
    )
    return L.next_token_loss(logits, batch["labels"]) + 0.01 * aux


# --------------------------------------------------------------------------
# Decode (one token, KV caches)
# --------------------------------------------------------------------------
class LMCache(NamedTuple):
    kv: Any  # KVCache with leading (L,) stacked axis
    cross_kv: Any | None = None  # ((G,B,T,kv,hd) k, v) for vlm


def lm_cache_descs(cfg: ArchConfig, batch: int, cache_len: int) -> LMCache:
    t = min(cache_len, cfg.window) if cfg.window else cache_len
    kv = map_stacked(cfg.n_layers, L.kv_cache_descs(batch, t, cfg.n_kv, cfg.hd, cfg.dtype))
    cross = None
    if cfg.cross_every:
        n_cross = cfg.n_layers // cfg.cross_every
        ck = ParamDesc((n_cross, batch, cfg.vision_tokens, cfg.n_kv, cfg.hd),
                       (None, "batch", None, "kv_heads", None), dtype=cfg.dtype, init="zeros")
        cross = (ck, ck)
    return LMCache(kv=kv, cross_kv=cross)


def lm_decode(
    params: dict,
    cfg: ArchConfig,
    cache: LMCache,
    tokens: jax.Array,  # (B, 1)
    active: jax.Array | None = None,  # (B,) live-slot mask (continuous batching)
    tiers: jax.Array | None = None,  # (B,) per-slot quality-tier indices
    demand: int | None = None,  # static batch plane-demand floor (min live tier)
) -> tuple[jax.Array, LMCache]:
    x = L.embed(params["embed"], tokens, cfg.dtype)

    def body(x, inp):
        bp, c = inp
        h, c2 = L.decode_attention(
            bp["attn"], L.rmsnorm(x, bp["ln1"]), c,
            theta=cfg.rope_theta, window=cfg.window, active=active,
            tiers=tiers, demand=demand,
        )
        x = x + h
        y = L.rmsnorm(x, bp["ln2"])
        if cfg.moe is not None:
            f, _ = L.moe(bp["moe"], y, top_k=cfg.moe.top_k,
                         capacity_factor=cfg.moe.capacity_factor,
                         active=active)
        else:
            f = L.mlp(bp["mlp"], y, tiers=tiers, demand=demand)
        return x + f, c2

    if not cfg.cross_every:
        x, new_kv = xscan(body, x, (params["blocks"], cache.kv))
        new_cache = LMCache(kv=new_kv)
    else:
        n_cross = cfg.n_layers // cfg.cross_every
        grouped_b = jax.tree_util.tree_map(
            lambda a: a.reshape(n_cross, cfg.cross_every, *a.shape[1:]),
            params["blocks"],
        )
        grouped_c = jax.tree_util.tree_map(
            lambda a: a.reshape(n_cross, cfg.cross_every, *a.shape[1:]), cache.kv
        )

        def group(x, inp):
            gb, gc, cp, ckv = inp
            x, c2 = xscan(body, x, (gb, gc))
            x = _cross_block_fwd(cp, x, ckv)
            return x, c2

        x, new_kv_g = xscan(
            group, x, (grouped_b, grouped_c, params["cross_blocks"], cache.cross_kv)
        )
        new_kv = jax.tree_util.tree_map(
            lambda a: a.reshape(cfg.n_layers, *a.shape[2:]), new_kv_g
        )
        new_cache = LMCache(kv=new_kv, cross_kv=cache.cross_kv)

    x = L.rmsnorm(x, params["final_norm"])
    return L.lm_head(params["embed"], x, tiers=tiers, demand=demand), new_cache


def lm_prefill(
    params: dict,
    cfg: ArchConfig,
    cache: LMCache,
    tokens: jax.Array,   # (B, S) left-padded prompts
    lengths: jax.Array,  # (B,) real token count per slot
    tiers: jax.Array | None = None,  # (B,) per-slot quality-tier indices
    demand: int | None = None,  # static plane-demand floor for this prompt batch
) -> tuple[LMCache, jax.Array]:
    """One-dispatch cache prefill: the whole left-padded prompt runs through
    a single causal-masked forward, so packed weights stream ONCE per
    prompt instead of once per token (the scanned per-token decode streamed
    every bit-plane S times).  Left-pad positions are masked out of
    attention and recorded in the cache, so pad tokens never pollute the
    KV entries another prompt attends to — for dense FFNs that makes a
    prompt's outputs exactly batch-invariant; MoE tokens (pads included)
    still share expert capacity, the same cross-slot coupling the scanned
    prefill had.  Returns (cache, last-position logits (B, V)) — same
    contract as the scanned prefill."""
    b, s = tokens.shape
    pad = (s - lengths).astype(jnp.int32)
    x = L.embed(params["embed"], tokens, cfg.dtype)
    positions = jnp.maximum(
        jnp.arange(s, dtype=jnp.int32)[None, :] - pad[:, None], 0
    )

    def body(x, inp):
        bp, c = inp
        h, c2 = L.prefill_attention(
            bp["attn"], L.rmsnorm(x, bp["ln1"]), c,
            positions=positions, pad=pad,
            theta=cfg.rope_theta, window=cfg.window, tiers=tiers,
            demand=demand,
        )
        x = constrain(x + h, ("batch", "seq_act", None))
        y = L.rmsnorm(x, bp["ln2"])
        if cfg.moe is not None:
            f, _ = L.moe(bp["moe"], y, top_k=cfg.moe.top_k,
                         capacity_factor=cfg.moe.capacity_factor)
        else:
            f = L.mlp(bp["mlp"], y, tiers=tiers, demand=demand)
        return x + f, c2

    x, new_kv = xscan(body, x, (params["blocks"], cache.kv))
    x = L.rmsnorm(x[:, -1:], params["final_norm"])  # only the last position
    logits = L.lm_head(params["embed"], x, tiers=tiers,
                       demand=demand)  # feeds the first sample
    return LMCache(kv=new_kv), logits[:, 0]


def lm_verify(
    params: dict,
    cfg: ArchConfig,
    cache: LMCache,
    tokens: jax.Array,  # (B, W) verify windows: [last emitted, draft_1..k]
    start: jax.Array,   # (B,) first cache index of each slot's window
    wlen: jax.Array,    # (B,) window tokens per slot (0 = lane not verifying)
    spec: jax.Array,    # (B,) speculating-lane mask (gates MoE capacity)
    tiers: jax.Array | None = None,  # (B,) per-slot VERIFY tier indices
    demand: int | None = None,  # static plane-demand floor (min verify tier)
) -> tuple[jax.Array, LMCache]:
    """Batched multi-position forward for self-speculative verify: one
    dispatch scores a whole drafted window per slot at the slot's verify
    tier, streaming the packed weights ONCE instead of once per drafted
    token.  Structured like :func:`lm_prefill` but anchored mid-stream:
    each lane's window lands at cache indices ``[start, start+wlen)``,
    overwriting the draft-tier KV the draft ticks wrote there, and logits
    come back for EVERY window position (the acceptance compare needs
    them all).  Dense FFN lanes are exactly independent, so a verified
    token equals the plain per-token decode bit-for-bit; MoE keeps the
    usual cross-slot capacity coupling.  Attention-only stacks with
    full-length caches only."""
    if cfg.cross_every:
        raise ValueError("speculative verify requires an attention-only stack")
    if cfg.window is not None:
        raise ValueError("speculative verify requires a full-length KV cache")
    x = L.embed(params["embed"], tokens, cfg.dtype)

    def body(x, inp):
        bp, c = inp
        h, c2 = L.verify_attention(
            bp["attn"], L.rmsnorm(x, bp["ln1"]), c,
            start=start, wlen=wlen, theta=cfg.rope_theta,
            tiers=tiers, demand=demand,
        )
        x = constrain(x + h, ("batch", "seq_act", None))
        y = L.rmsnorm(x, bp["ln2"])
        if cfg.moe is not None:
            f, _ = L.moe(bp["moe"], y, top_k=cfg.moe.top_k,
                         capacity_factor=cfg.moe.capacity_factor,
                         active=spec)
        else:
            f = L.mlp(bp["mlp"], y, tiers=tiers, demand=demand)
        return x + f, c2

    x, new_kv = xscan(body, x, (params["blocks"], cache.kv))
    x = L.rmsnorm(x, params["final_norm"])
    logits = L.lm_head(params["embed"], x, tiers=tiers, demand=demand)
    return logits, LMCache(kv=new_kv)


def lm_cache_insert_slot(live: LMCache, one: LMCache, slot: jax.Array) -> LMCache:
    """Admit a request: write a freshly prefilled single-slot cache (batch-1
    leaves from :func:`lm_prefill` on a zeroed cache) into lane ``slot`` of
    a live multi-slot cache.  Every ``LMCache.kv`` leaf carries batch at
    axis 1 (axis 0 is the stacked layer axis), so one traced
    dynamic-update-slice per leaf replaces the whole lane — k/v entries,
    per-slot ``pos`` and ``pad`` — without touching the other lanes, and
    ``slot`` stays a traced scalar (admission never recompiles)."""
    kv = jax.tree_util.tree_map(
        lambda a, b: jax.lax.dynamic_update_slice_in_dim(
            a, b.astype(a.dtype), slot, axis=1
        ),
        live.kv, one.kv,
    )
    return LMCache(kv=kv, cross_kv=live.cross_kv)


def vision_prefill_cross_kv(params: dict, cfg: ArchConfig, vision_embeds: jax.Array):
    """Precompute the (G, B, T_img, kv, hd) cross K/V for decode."""
    return jax.vmap(lambda cp: L.cross_kv(cp["attn"], vision_embeds))(
        params["cross_blocks"]
    )

"""Parameter-descriptor machinery shared by every model family.

Models describe their parameters as a pytree of :class:`ParamDesc` (shape +
logical axis names + init).  From one description we derive:

  * real initialized arrays            (``init_params``)  — smoke tests, examples
  * ShapeDtypeStruct stand-ins         (``abstract_params``) — the multi-pod dry-run
  * jax.sharding.PartitionSpec trees   (``partition_specs``) — pjit in/out shardings

Logical axis names decouple the model definition from the mesh: a rules dict
maps e.g. "mlp" -> ("model",), "embed" -> ("data",) (FSDP), and any dim whose
size is not divisible by its mesh axes falls back to replicated — which is how
e.g. smollm's 9 attention heads or whisper's 51865-token vocab stay correct on
a 16-wide model axis without per-arch special cases.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

# --------------------------------------------------------------------------
# Scan with a global unroll switch.
#
# XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
# count, so cost_analysis() on a scanned-layers module under-reports
# FLOPs/bytes by ~n_layers.  The dry-run therefore compiles small "probe"
# modules with every scan fully unrolled (set_scan_unroll(True)) and
# extrapolates; the production step keeps rolled scans for fast compiles.
# --------------------------------------------------------------------------
_SCAN_UNROLL = False


def set_scan_unroll(value: bool) -> None:
    global _SCAN_UNROLL
    _SCAN_UNROLL = bool(value)


def xscan(body, carry, xs, length=None):
    """jax.lax.scan honoring the global unroll switch (see above)."""
    return jax.lax.scan(body, carry, xs, length=length,
                        unroll=True if _SCAN_UNROLL else 1)


@dataclasses.dataclass(frozen=True)
class ParamDesc:
    """One parameter: shape, per-dim logical axes, dtype, initializer."""

    shape: tuple
    axes: tuple  # same length as shape; entries are logical names or None
    dtype: Any = jnp.float32
    init: str = "fan_in"  # fan_in | normal | zeros | ones | small
    scale: float = 1.0

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def _is_desc(x) -> bool:
    return isinstance(x, ParamDesc)


# Descriptor trees may contain WeightStore pytree nodes (quant/store.py)
# whose children are ParamDesc — e.g. quant.packed.packed_param_descs wraps
# planes/scales descriptors in PackedWeight.  Every tree_map below uses
# is_leaf=_is_desc, so it descends into those nodes and the derived
# abstract/real/PartitionSpec trees keep the same WeightStore structure,
# which is exactly what the jitted serve step takes as arguments.
is_desc = _is_desc


def _init_one(key, d: ParamDesc) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, d.dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, d.dtype)
    if d.init == "fan_in":
        fan_in = d.shape[-2] if len(d.shape) >= 2 else max(d.shape[0], 1)
        std = d.scale / np.sqrt(fan_in)
    elif d.init == "normal":
        std = d.scale * 0.02
    elif d.init == "small":
        std = d.scale * 0.006
    else:
        raise ValueError(f"unknown init {d.init!r}")
    return (jax.random.normal(key, d.shape, jnp.float32) * std).astype(d.dtype)


def init_params(key: jax.Array, descs) -> Any:
    """Materialize real arrays from a descriptor tree."""
    leaves, treedef = jax.tree_util.tree_flatten(descs, is_leaf=_is_desc)
    keys = jax.random.split(key, len(leaves))
    arrs = [_init_one(k, d) for k, d in zip(keys, leaves, strict=True)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def abstract_params(descs) -> Any:
    """ShapeDtypeStruct stand-ins (no allocation) for the dry-run."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), descs, is_leaf=_is_desc
    )


def spec_for_shape(
    shape, axes, rules: Mapping[str, Sequence[str]],
    mesh_axis_sizes: Mapping[str, int],
) -> P:
    """One tensor's PartitionSpec from logical axes under divisibility
    fallback: a dim is sharded over its mapped mesh axes only if the dim size
    is divisible by their product; otherwise replicated.  A mesh axis may
    shard at most one dim (first dim wins)."""
    used: set = set()
    entries = []
    for size, name in zip(shape, axes, strict=True):
        mesh_axes = tuple(rules.get(name, ())) if name else ()
        mesh_axes = tuple(a for a in mesh_axes if a not in used)
        prod = int(np.prod([mesh_axis_sizes[a] for a in mesh_axes])) if mesh_axes else 1
        if mesh_axes and size % prod == 0:
            used.update(mesh_axes)
            entries.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
        else:
            entries.append(None)
    return P(*entries)


def partition_specs(
    descs, rules: Mapping[str, Sequence[str]], mesh_axis_sizes: Mapping[str, int]
) -> Any:
    """Logical axes -> PartitionSpec tree (see spec_for_shape)."""
    return jax.tree_util.tree_map(
        lambda d: spec_for_shape(d.shape, d.axes, rules, mesh_axis_sizes),
        descs, is_leaf=_is_desc,
    )


# --------------------------------------------------------------------------
# Activation sharding constraints.
#
# XLA SPMD propagation through the 5-D GQA einsums / MoE scatters is not
# stable at 512 devices (it can silently replicate the batch dim, inflating
# per-device compute 16-32x).  Models therefore pin their key activations
# with `constrain(x, ("batch", None, "heads", None))` using the SAME logical
# axis names as params.  The rules are installed per-launch (dryrun/trainer);
# with no rules installed (CPU unit tests) constrain() is a no-op.
# --------------------------------------------------------------------------
_ACT_RULES: dict = {}
_ACT_MESH = None


def set_activation_rules(rules: Mapping[str, Sequence[str]] | None, mesh=None) -> None:
    global _ACT_RULES, _ACT_MESH
    _ACT_RULES = dict(rules) if rules else {}
    _ACT_MESH = mesh


def constrain(x: jax.Array, axes: tuple) -> jax.Array:
    if not _ACT_RULES or _ACT_MESH is None:
        return x
    sizes = dict(zip(_ACT_MESH.axis_names, _ACT_MESH.devices.shape, strict=True))
    spec = spec_for_shape(x.shape, axes, _ACT_RULES, sizes)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(_ACT_MESH, spec)
    )


def data_shard_count() -> int:
    """Product of the mesh axes carrying the batch under the installed
    activation rules (1 when no mesh is installed — CPU unit tests).

    Used by the MoE layer for shard-local capacity routing: the dispatch
    cumsum/scatter then never crosses a data-parallel boundary."""
    if not _ACT_RULES or _ACT_MESH is None:
        return 1
    sizes = dict(zip(_ACT_MESH.axis_names, _ACT_MESH.devices.shape, strict=True))
    return int(np.prod([sizes[a] for a in _ACT_RULES.get("batch", ()) if a in sizes]))


def count_params(descs) -> int:
    return sum(
        int(np.prod(d.shape))
        for d in jax.tree_util.tree_leaves(descs, is_leaf=_is_desc)
    )


def param_bytes(descs) -> int:
    return sum(
        int(np.prod(d.shape)) * jnp.dtype(d.dtype).itemsize
        for d in jax.tree_util.tree_leaves(descs, is_leaf=_is_desc)
    )


# Convenience constructors -------------------------------------------------
def dense(d_in: int, d_out: int, in_ax: str | None, out_ax: str | None,
          dtype=jnp.float32, **kw) -> ParamDesc:
    return ParamDesc((d_in, d_out), (in_ax, out_ax), dtype=dtype, **kw)


def stacked(n: int, desc: ParamDesc, axis_name: str | None = "layers") -> ParamDesc:
    """Prepend a scan-stacked layer axis."""
    return ParamDesc(
        (n, *desc.shape), (axis_name, *desc.axes), dtype=desc.dtype,
        init=desc.init, scale=desc.scale,
    )


def map_stacked(n: int, tree, axis_name: str | None = "layers"):
    """stacked() over a whole descriptor tree."""
    return jax.tree_util.tree_map(
        lambda d: stacked(n, d, axis_name), tree, is_leaf=_is_desc
    )

"""Jamba-style hybrid: blocks of `period` layers = 1 attention + (period-1)
Mamba2 mixers, FFN after every mixer alternating dense / MoE (arXiv:2403.19887).

Scan runs over the (n_layers // period) blocks; the 8 sublayers inside a
block are unrolled (small constant).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.base import map_stacked, xscan


def _ssm_cfg(cfg: ArchConfig) -> S.SSMConfig:
    d_inner = 2 * cfg.d_model
    return S.SSMConfig(
        d_model=cfg.d_model,
        d_inner=d_inner,
        n_heads=d_inner // cfg.ssm_head_dim,
        head_dim=cfg.ssm_head_dim,
        state=cfg.ssm_state,
        n_groups=cfg.ssm_groups,
        chunk=cfg.ssm_chunk,
    )


def _ffn_counts(cfg: ArchConfig) -> tuple[int, int]:
    period = cfg.hybrid.period
    n_moe = sum(1 for i in range(period) if i % cfg.hybrid.moe_every == 1)
    return period - n_moe, n_moe  # (dense, moe)


def hybrid_descs(cfg: ArchConfig) -> dict:
    period = cfg.hybrid.period
    n_blocks = cfg.n_layers // period
    sc = _ssm_cfg(cfg)
    n_dense, n_moe = _ffn_counts(cfg)
    block = {
        "attn_ln": L.rmsnorm_desc(cfg.d_model),
        "attn": L.attn_descs(cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd, dtype=cfg.dtype),
        "mamba_ln": map_stacked(period - 1, L.rmsnorm_desc(cfg.d_model), None),
        "mamba": map_stacked(period - 1, S.ssm_descs(sc, dtype=cfg.dtype), None),
        "ffn_ln": map_stacked(period, L.rmsnorm_desc(cfg.d_model), None),
        "dense_ffn": map_stacked(n_dense, L.mlp_descs(cfg.d_model, cfg.d_ff, dtype=cfg.dtype), None),
        "moe_ffn": map_stacked(n_moe, L.moe_descs(cfg.d_model, cfg.d_ff, cfg.moe.n_experts, dtype=cfg.dtype), None),
    }
    return {
        "embed": L.embed_descs(cfg.vocab, cfg.d_model, dtype=cfg.dtype),
        "final_norm": L.rmsnorm_desc(cfg.d_model),
        "blocks": map_stacked(n_blocks, block),
    }


def _slice(tree, i: int):
    return jax.tree_util.tree_map(lambda a: a[i], tree)


def _ffn(cfg: ArchConfig, bp: dict, x: jax.Array, layer_in_block: int):
    """FFN for sublayer i: MoE if i % moe_every == 1 else dense."""
    y = L.rmsnorm(x, bp["ffn_ln"][layer_in_block])
    if layer_in_block % cfg.hybrid.moe_every == 1:
        f, aux = L.moe(
            _slice(bp["moe_ffn"], layer_in_block // cfg.hybrid.moe_every),
            y, top_k=cfg.moe.top_k, capacity_factor=cfg.moe.capacity_factor,
        )
    else:
        dense_idx = (layer_in_block + 1) // cfg.hybrid.moe_every
        f, aux = L.mlp(_slice(bp["dense_ffn"], dense_idx), y), jnp.float32(0.0)
    return x + f, aux


def hybrid_forward(params: dict, cfg: ArchConfig, tokens: jax.Array):
    b, s = tokens.shape
    sc = _ssm_cfg(cfg)
    x = L.embed(params["embed"], tokens, cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    period = cfg.hybrid.period

    def block_fwd(carry, bp):
        x, aux = carry
        h = L.attention(bp["attn"], L.rmsnorm(x, bp["attn_ln"]),
                        positions=positions, theta=cfg.rope_theta)
        x, a = _ffn(cfg, bp, x + h, 0)
        aux = aux + a
        for i in range(1, period):
            h = S.ssm_forward(_slice(bp["mamba"], i - 1),
                              L.rmsnorm(x, bp["mamba_ln"][i - 1]), sc)
            x, a = _ffn(cfg, bp, x + h, i)
            aux = aux + a
        return (x, aux), None

    body = jax.checkpoint(block_fwd) if cfg.remat else block_fwd
    (x, aux), _ = xscan(body, (x, jnp.float32(0.0)), params["blocks"])
    x = L.rmsnorm(x, params["final_norm"])
    return L.lm_head(params["embed"], x), aux / cfg.n_layers


def hybrid_loss(params: dict, cfg: ArchConfig, batch: dict) -> jax.Array:
    logits, aux = hybrid_forward(params, cfg, batch["tokens"])
    return L.next_token_loss(logits, batch["labels"]) + 0.01 * aux


class HybridCache(NamedTuple):
    kv: Any  # KVCache stacked (n_blocks, ...)
    ssm: Any  # SSMState stacked (n_blocks, period-1, ...)


def hybrid_cache_descs(cfg: ArchConfig, batch: int, cache_len: int) -> HybridCache:
    period = cfg.hybrid.period
    n_blocks = cfg.n_layers // period
    sc = _ssm_cfg(cfg)
    t = min(cache_len, cfg.window) if cfg.window else cache_len
    return HybridCache(
        kv=map_stacked(n_blocks, L.kv_cache_descs(batch, t, cfg.n_kv, cfg.hd, cfg.dtype)),
        ssm=map_stacked(n_blocks, map_stacked(period - 1, S.ssm_state_descs(sc, batch, cfg.dtype), None)),
    )


def hybrid_decode(params: dict, cfg: ArchConfig, cache: HybridCache, tokens: jax.Array):
    sc = _ssm_cfg(cfg)
    period = cfg.hybrid.period
    x = L.embed(params["embed"], tokens, cfg.dtype)

    def block_fwd(x, inp):
        bp, kvc, ssmc = inp
        h, kv2 = L.decode_attention(bp["attn"], L.rmsnorm(x, bp["attn_ln"]), kvc,
                                    theta=cfg.rope_theta, window=cfg.window)
        x, _ = _ffn(cfg, bp, x + h, 0)
        new_states = []
        for i in range(1, period):
            st = _slice(ssmc, i - 1)
            h, st2 = S.ssm_decode(_slice(bp["mamba"], i - 1),
                                  L.rmsnorm(x, bp["mamba_ln"][i - 1]), st, sc)
            new_states.append(st2)
            x, _ = _ffn(cfg, bp, x + h, i)
        stacked = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, axis=0), *new_states
        )
        return x, (kv2, stacked)

    x, (new_kv, new_ssm) = xscan(
        block_fwd, x, (params["blocks"], cache.kv, cache.ssm)
    )
    x = L.rmsnorm(x, params["final_norm"])
    return L.lm_head(params["embed"], x), HybridCache(kv=new_kv, ssm=new_ssm)

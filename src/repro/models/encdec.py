"""Whisper-style encoder-decoder transformer (arXiv:2212.04356).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, enc_seq, d_model) directly.  RMSNorm is used
in place of LayerNorm (TPU-idiomatic; noted in DESIGN.md §8); the MLP is the
paper's 2-layer GELU.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.base import ParamDesc, dense, map_stacked, xscan
from repro.models.layers import W as L_W


def _gelu_mlp_descs(d: int, ff: int, dtype) -> dict:
    return {"wi": dense(d, ff, "embed", "mlp", dtype=dtype),
            "wo": dense(ff, d, "mlp", "embed", dtype=dtype)}


def _gelu_mlp(p: dict, x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ L_W(p["wi"]).astype(x.dtype)) @ L_W(p["wo"]).astype(x.dtype)


def _enc_block_descs(cfg: ArchConfig) -> dict:
    return {
        "ln1": L.rmsnorm_desc(cfg.d_model),
        "attn": L.attn_descs(cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd, dtype=cfg.dtype),
        "ln2": L.rmsnorm_desc(cfg.d_model),
        "mlp": _gelu_mlp_descs(cfg.d_model, cfg.d_ff, cfg.dtype),
    }


def _dec_block_descs(cfg: ArchConfig) -> dict:
    return {
        "ln1": L.rmsnorm_desc(cfg.d_model),
        "self_attn": L.attn_descs(cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd, dtype=cfg.dtype),
        "ln_x": L.rmsnorm_desc(cfg.d_model),
        "cross_attn": L.attn_descs(cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd, dtype=cfg.dtype),
        "ln2": L.rmsnorm_desc(cfg.d_model),
        "mlp": _gelu_mlp_descs(cfg.d_model, cfg.d_ff, cfg.dtype),
    }


def encdec_descs(cfg: ArchConfig) -> dict:
    return {
        "embed": L.embed_descs(cfg.vocab, cfg.d_model, dtype=cfg.dtype),
        "enc_blocks": map_stacked(cfg.enc_layers, _enc_block_descs(cfg)),
        "dec_blocks": map_stacked(cfg.n_layers, _dec_block_descs(cfg)),
        "enc_norm": L.rmsnorm_desc(cfg.d_model),
        "final_norm": L.rmsnorm_desc(cfg.d_model),
    }


def encode(params: dict, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, enc_seq, d_model) precomputed embeddings (stub frontend)."""
    b, t, d = frames.shape
    pos = jnp.asarray(L.sinusoidal_pos_emb(t, d), dtype=cfg.dtype)
    x = frames.astype(cfg.dtype) + pos[None]

    def body(x, bp):
        h = L.attention(bp["attn"], L.rmsnorm(x, bp["ln1"]),
                        positions=None, causal=False)
        x = x + h
        return x + _gelu_mlp(bp["mlp"], L.rmsnorm(x, bp["ln2"])), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = xscan(body_fn, x, params["enc_blocks"])
    return L.rmsnorm(x, params["enc_norm"])


def encdec_forward(params: dict, cfg: ArchConfig, frames: jax.Array, tokens: jax.Array):
    """Teacher-forced training forward -> (logits, aux=0)."""
    enc = encode(params, cfg, frames)
    b, s = tokens.shape
    pos = jnp.asarray(L.sinusoidal_pos_emb(s, cfg.d_model), dtype=cfg.dtype)
    x = L.embed(params["embed"], tokens, cfg.dtype) + pos[None]

    def body(x, bp):
        h = L.attention(bp["self_attn"], L.rmsnorm(x, bp["ln1"]),
                        positions=None, causal=True)
        x = x + h
        ckv = L.cross_kv(bp["cross_attn"], enc)
        x = x + L.cross_attention(bp["cross_attn"], L.rmsnorm(x, bp["ln_x"]), ckv)
        return x + _gelu_mlp(bp["mlp"], L.rmsnorm(x, bp["ln2"])), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = xscan(body_fn, x, params["dec_blocks"])
    x = L.rmsnorm(x, params["final_norm"])
    return L.lm_head(params["embed"], x), jnp.float32(0.0)


def encdec_loss(params: dict, cfg: ArchConfig, batch: dict) -> jax.Array:
    logits, _ = encdec_forward(params, cfg, batch["frames"], batch["tokens"])
    return L.next_token_loss(logits, batch["labels"])


class EncDecCache(NamedTuple):
    kv: Any  # self-attn KVCache stacked (L_dec, ...)
    cross_k: Any  # (L_dec, B, enc_seq, kv, hd)
    cross_v: Any


def encdec_cache_descs(cfg: ArchConfig, batch: int, cache_len: int) -> EncDecCache:
    ck = ParamDesc((cfg.n_layers, batch, cfg.enc_seq, cfg.n_kv, cfg.hd),
                   (None, "batch", None, "kv_heads", None), dtype=cfg.dtype, init="zeros")
    return EncDecCache(
        kv=map_stacked(cfg.n_layers, L.kv_cache_descs(batch, cache_len, cfg.n_kv, cfg.hd, cfg.dtype)),
        cross_k=ck,
        cross_v=ck,
    )


def encdec_prefill_cross(params: dict, cfg: ArchConfig, frames: jax.Array):
    """Encoder pass + per-decoder-layer cross K/V (run once per request)."""
    enc = encode(params, cfg, frames)
    ks, vs = jax.vmap(lambda bp: L.cross_kv(bp["cross_attn"], enc))(params["dec_blocks"])
    return ks, vs


def _sin_pos_at(pos, d: int, dtype):
    """Sinusoidal position embedding rows at traced (B,) position indices."""
    i = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32)[..., None] / jnp.power(10000.0, 2.0 * i / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def encdec_decode(params: dict, cfg: ArchConfig, cache: EncDecCache, tokens: jax.Array):
    b = tokens.shape[0]
    # current position = layer-0 self-attn per-slot cache counters (B,)
    pos0 = cache.kv.pos[0]
    pos = _sin_pos_at(pos0, cfg.d_model, cfg.dtype)  # (B, d)
    x = L.embed(params["embed"], tokens, cfg.dtype) + pos[:, None, :]

    def body(x, inp):
        bp, kvc, ck, cv = inp
        # whisper uses absolute sinusoidal positions, no RoPE (matches encode)
        h, kv2 = L.decode_attention(bp["self_attn"], L.rmsnorm(x, bp["ln1"]), kvc,
                                    use_rope=False)
        x = x + h
        x = x + L.cross_attention(bp["cross_attn"], L.rmsnorm(x, bp["ln_x"]), (ck, cv))
        return x + _gelu_mlp(bp["mlp"], L.rmsnorm(x, bp["ln2"])), kv2

    x, new_kv = xscan(
        body, x, (params["dec_blocks"], cache.kv, cache.cross_k, cache.cross_v)
    )
    x = L.rmsnorm(x, params["final_norm"])
    return L.lm_head(params["embed"], x), EncDecCache(
        kv=new_kv, cross_k=cache.cross_k, cross_v=cache.cross_v
    )

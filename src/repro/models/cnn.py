"""The paper's own evaluation models: LeNet (MNIST) and the 4-layer ConvNet
(CIFAR-10), in pure JAX.  These are what Tables III and Figs. 7-10 are run on.
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp

from repro.models.base import ParamDesc


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    kh: int
    kw: int
    cin: int
    cout: int
    pool: bool  # 2x2 max pool after


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    input_hw: tuple
    input_c: int
    convs: tuple
    fc: tuple  # hidden fc widths
    n_classes: int

    @property
    def conv_layers(self):
        return self.convs


LENET = CNNConfig(
    name="lenet",
    input_hw=(28, 28),
    input_c=1,
    convs=(ConvSpec(5, 5, 1, 6, True), ConvSpec(5, 5, 6, 16, True)),
    fc=(120, 84),
    n_classes=10,
)

CONVNET4 = CNNConfig(
    name="convnet4",
    input_hw=(32, 32),
    input_c=3,
    convs=(
        ConvSpec(3, 3, 3, 32, False),
        ConvSpec(3, 3, 32, 32, True),
        ConvSpec(3, 3, 32, 64, False),
        ConvSpec(3, 3, 64, 64, True),
    ),
    fc=(512,),
    n_classes=10,
)


def _flat_dim(cfg: CNNConfig) -> int:
    h, w = cfg.input_hw
    c = cfg.input_c
    for cs in cfg.convs:
        # 'SAME' conv keeps H,W; pooling halves
        c = cs.cout
        if cs.pool:
            h, w = h // 2, w // 2
    return h * w * c


def cnn_descs(cfg: CNNConfig) -> dict:
    descs = {"convs": [], "fcs": []}
    for cs in cfg.convs:
        descs["convs"].append({
            "w": ParamDesc((cs.kh, cs.kw, cs.cin, cs.cout), (None, None, None, None)),
            "b": ParamDesc((cs.cout,), (None,), init="zeros"),
        })
    dims = [_flat_dim(cfg), *cfg.fc, cfg.n_classes]
    for i in range(len(dims) - 1):
        descs["fcs"].append({
            "w": ParamDesc((dims[i], dims[i + 1]), (None, None)),
            "b": ParamDesc((dims[i + 1],), (None,), init="zeros"),
        })
    return descs


def cnn_forward(params: dict, cfg: CNNConfig, images: jax.Array) -> jax.Array:
    """images: (B, H, W, C) f32 -> logits (B, n_classes)."""
    x = images.astype(jnp.float32)
    for cs, p in zip(cfg.convs, params["convs"], strict=True):
        x = jax.lax.conv_general_dilated(
            x, p["w"], window_strides=(1, 1), padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        ) + p["b"]
        x = jax.nn.relu(x)
        if cs.pool:
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
            )
    x = x.reshape(x.shape[0], -1)
    for i, p in enumerate(params["fcs"]):
        x = x @ p["w"] + p["b"]
        if i < len(params["fcs"]) - 1:
            x = jax.nn.relu(x)
    return x


def cnn_loss(params: dict, cfg: CNNConfig, batch: dict) -> jax.Array:
    logits = cnn_forward(params, cfg, batch["images"])
    return -jnp.mean(
        jnp.take_along_axis(
            jax.nn.log_softmax(logits, -1), batch["labels"][:, None], axis=1
        )
    )


def cnn_accuracy(params: dict, cfg: CNNConfig, images, labels) -> float:
    logits = cnn_forward(params, cfg, images)
    return float(jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32)))


def conv_layer_shapes(cfg: CNNConfig):
    """(name, H, W, C, Num) per conv layer for the Eq. 11/12 model."""
    from repro.core.energy import LayerShape

    return [
        LayerShape(f"conv{i}", cs.kh, cs.kw, cs.cin, cs.cout)
        for i, cs in enumerate(cfg.convs)
    ]

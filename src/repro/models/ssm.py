"""Mamba2 (SSD — state-space duality) mixer, arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm (quadratic within chunks of
length L, linear across chunks via a state-passing scan) — the real
algorithm, so HLO FLOPs are faithful.  Decode keeps a constant-size state
(B, H, N, P) + a causal-conv ring buffer, which is what makes the
``long_500k`` shape tractable for SSM/hybrid archs.

Head layout: d_inner = n_heads * head_dim (P); one shared B/C per group
(n_groups=1 for mamba2-1.3b; jamba uses 8).  Heads shard over the mesh
"model" axis; B/C/state stay replicated (they are shared across heads).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.base import ParamDesc, constrain, dense, xscan
from repro.models.layers import W as L_W, rmsnorm, rmsnorm_desc


class SSMConfig(NamedTuple):
    d_model: int
    d_inner: int
    n_heads: int
    head_dim: int
    state: int  # N
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 256


def ssm_descs(c: SSMConfig, dtype=jnp.float32) -> dict:
    gn = c.n_groups * c.state
    return {
        "wz": dense(c.d_model, c.d_inner, "embed", "heads_inner", dtype=dtype),
        "wx": dense(c.d_model, c.d_inner, "embed", "heads_inner", dtype=dtype),
        "wB": dense(c.d_model, gn, "embed", None, dtype=dtype),
        "wC": dense(c.d_model, gn, "embed", None, dtype=dtype),
        "wdt": dense(c.d_model, c.n_heads, "embed", None, dtype=dtype),
        "conv_x": ParamDesc((c.conv_width, c.d_inner), (None, "heads_inner"), dtype=dtype, init="normal"),
        "conv_B": ParamDesc((c.conv_width, gn), (None, None), dtype=dtype, init="normal"),
        "conv_C": ParamDesc((c.conv_width, gn), (None, None), dtype=dtype, init="normal"),
        "a_log": ParamDesc((c.n_heads,), (None,), init="zeros"),
        "D": ParamDesc((c.n_heads,), (None,), init="ones"),
        "dt_bias": ParamDesc((c.n_heads,), (None,), init="zeros"),
        "norm": rmsnorm_desc(c.d_inner),
        "wo": dense(c.d_inner, c.d_model, "heads_inner", "embed", dtype=dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv: x (B,S,D), w (W,D) -> (B,S,D)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    return jax.nn.silu(out)


def _segsum(dlog: jax.Array) -> jax.Array:
    """dlog (..., L, H) -> (..., H, L, L) with [i, j] = sum_{k=j+1..i} dlog_k
    for i >= j, -inf otherwise (log of the intra-chunk decay matrix)."""
    length = dlog.shape[-2]
    x = jnp.moveaxis(dlog, -1, -2)  # (..., H, L)
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]  # [i, j] = cs_i - cs_j
    i = jnp.arange(length)[:, None]
    j = jnp.arange(length)[None, :]
    return jnp.where(i >= j, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) — post-softplus
    a: jax.Array,  # (H,) — negative decay rate (-exp(a_log))
    bmat: jax.Array,  # (B, S, G, N)
    cmat: jax.Array,  # (B, S, G, N)
    chunk: int,
    h0: jax.Array | None = None,  # (B, H, N, P)
):
    """Chunked SSD scan.  Returns (y (B,S,H,P), h_final (B,H,N,P))."""
    b, s_orig, h, p = x.shape
    g, n = bmat.shape[2], bmat.shape[3]
    hpg = h // g  # heads per group
    if s_orig % chunk:
        # pad to a whole number of chunks; padded steps have x=0 and dt=0
        # (decay exp(0)=1), so they neither emit nor perturb the state
        pad = chunk - s_orig % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    s = x.shape[1]
    nc = s // chunk
    f32 = jnp.float32

    xc = x.reshape(b, nc, chunk, h, p).astype(f32)
    dtc = dt.reshape(b, nc, chunk, h).astype(f32)
    bc = bmat.reshape(b, nc, chunk, g, n).astype(f32)
    cc = cmat.reshape(b, nc, chunk, g, n).astype(f32)

    dlog = dtc * a.astype(f32)  # (b, nc, L, H), negative
    seg = _segsum(dlog)  # (b, nc, H, L, L)
    lmat = jnp.exp(seg)

    # intra-chunk (quadratic, "attention-like" dual form)
    # scores[b,c,g,i,j] = C_i . B_j  -> broadcast over heads in group
    cb = jnp.einsum("bclgn,bcmgn->bcglm", cc, bc)  # (b,nc,g,L,L)
    cb = cb.reshape(b, nc, g, 1, chunk, chunk)
    lm = lmat.reshape(b, nc, g, hpg, chunk, chunk)
    dtj = jnp.moveaxis(dtc.reshape(b, nc, chunk, g, hpg), 2, 4)  # (b,nc,g,hpg,L)
    att = cb * lm * dtj[:, :, :, :, None, :]
    y_intra = jnp.einsum(
        "bcghlm,bcmghp->bclghp",
        att,
        xc.reshape(b, nc, chunk, g, hpg, p),
    )  # (b, nc, L, g, hpg, p)

    # end-of-chunk states: S_c = sum_j exp(cs_L - cs_j) dt_j B_j (x) x_j
    csum = jnp.cumsum(dlog, axis=2)  # (b, nc, L, H)
    decay_to_end = jnp.exp(csum[:, :, -1:, :] - csum)  # (b, nc, L, H)
    wdt = decay_to_end * dtc  # (b, nc, L, H)
    s_c = jnp.einsum(
        "bclgn,bclgh,bclghp->bcghnp",
        bc,
        wdt.reshape(b, nc, chunk, g, hpg),
        xc.reshape(b, nc, chunk, g, hpg, p),
    )  # (b, nc, g, hpg, n, p)

    # inter-chunk recurrence over nc (linear scan)
    total_decay = jnp.exp(csum[:, :, -1, :]).reshape(b, nc, g, hpg)  # per chunk

    hinit = (
        jnp.zeros((b, g, hpg, n, p), f32)
        if h0 is None
        else h0.reshape(b, g, hpg, n, p).astype(f32)
    )

    def step(hprev, inp):
        sc, td = inp  # (b,g,hpg,n,p), (b,g,hpg)
        hnew = td[..., None, None] * hprev + sc
        return hnew, hprev

    scs = jnp.moveaxis(s_c, 1, 0)  # (nc, b, g, hpg, n, p)
    tds = jnp.moveaxis(total_decay, 1, 0)
    h_last, h_prevs = xscan(step, hinit, (scs, tds))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # (b, nc, g, hpg, n, p)

    # inter-chunk contribution: y_i += C_i . (decay_to_i * h_prev)
    decay_in = jnp.exp(csum)  # (b, nc, L, H) — decay from chunk start to i
    y_inter = jnp.einsum(
        "bclgn,bcghnp,bclgh->bclghp",
        cc,
        h_prevs,
        decay_in.reshape(b, nc, chunk, g, hpg),
    )

    y = (y_intra + y_inter).reshape(b, s, h, p)[:, :s_orig]
    return y, h_last.reshape(b, h, n, p)


class SSMState(NamedTuple):
    h: jax.Array  # (B, H, N, P) f32
    conv_x: jax.Array  # (B, W-1, d_inner)
    conv_B: jax.Array  # (B, W-1, G*N)
    conv_C: jax.Array  # (B, W-1, G*N)


def ssm_state_descs(c: SSMConfig, batch: int, dtype=jnp.float32) -> SSMState:
    gn = c.n_groups * c.state
    w = c.conv_width - 1
    return SSMState(
        h=ParamDesc((batch, c.n_heads, c.state, c.head_dim), ("batch", "heads_inner", None, None), dtype=jnp.float32, init="zeros"),
        conv_x=ParamDesc((batch, w, c.d_inner), ("batch", None, "heads_inner"), dtype=dtype, init="zeros"),
        conv_B=ParamDesc((batch, w, gn), ("batch", None, None), dtype=dtype, init="zeros"),
        conv_C=ParamDesc((batch, w, gn), ("batch", None, None), dtype=dtype, init="zeros"),
    )


def ssm_forward(p: dict, x: jax.Array, c: SSMConfig) -> jax.Array:
    """Full-sequence mixer forward: x (B, S, d_model) -> (B, S, d_model)."""
    b, s, _ = x.shape
    z = constrain(x @ L_W(p["wz"]).astype(x.dtype), ("batch", None, "heads_inner"))
    xs = _causal_conv(x @ L_W(p["wx"]).astype(x.dtype), L_W(p["conv_x"]).astype(x.dtype))
    xs = constrain(xs, ("batch", None, "heads_inner"))
    bs = _causal_conv(x @ L_W(p["wB"]).astype(x.dtype), L_W(p["conv_B"]).astype(x.dtype))
    cs = _causal_conv(x @ L_W(p["wC"]).astype(x.dtype), L_W(p["conv_C"]).astype(x.dtype))
    dt = jax.nn.softplus(
        (x @ L_W(p["wdt"]).astype(x.dtype)).astype(jnp.float32) + p["dt_bias"]
    )
    a = -jnp.exp(p["a_log"])

    xh = xs.reshape(b, s, c.n_heads, c.head_dim)
    bm = bs.reshape(b, s, c.n_groups, c.state)
    cm = cs.reshape(b, s, c.n_groups, c.state)
    y, _ = ssd_chunked(xh, dt, a, bm, cm, c.chunk)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, c.d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    return constrain(y @ L_W(p["wo"]).astype(x.dtype), ("batch", None, None))


def ssm_decode(
    p: dict, x: jax.Array, state: SSMState, c: SSMConfig
) -> tuple[jax.Array, SSMState]:
    """Single-token decode: x (B, 1, d_model)."""
    b = x.shape[0]
    xt = x[:, 0]  # (B, d)
    z = xt @ L_W(p["wz"]).astype(x.dtype)

    def conv_step(buf, xin, w):
        # buf (B, W-1, D) holds the previous W-1 inputs
        full = jnp.concatenate([buf, xin[:, None]], axis=1)  # (B, W, D)
        out = jnp.einsum("bwd,wd->bd", full.astype(jnp.float32), w.astype(jnp.float32))
        return jax.nn.silu(out).astype(x.dtype), full[:, 1:]

    xs, nconv_x = conv_step(state.conv_x, xt @ L_W(p["wx"]).astype(x.dtype), p["conv_x"])
    bs, nconv_B = conv_step(state.conv_B, xt @ L_W(p["wB"]).astype(x.dtype), p["conv_B"])
    cs, nconv_C = conv_step(state.conv_C, xt @ L_W(p["wC"]).astype(x.dtype), p["conv_C"])

    dt = jax.nn.softplus(
        (xt @ L_W(p["wdt"]).astype(x.dtype)).astype(jnp.float32) + p["dt_bias"]
    )  # (B, H)
    a = -jnp.exp(p["a_log"])  # (H,)
    decay = jnp.exp(dt * a)  # (B, H)

    xh = xs.reshape(b, c.n_heads, c.head_dim).astype(jnp.float32)
    bm = bs.reshape(b, c.n_groups, c.state).astype(jnp.float32)
    cm = cs.reshape(b, c.n_groups, c.state).astype(jnp.float32)
    hpg = c.n_heads // c.n_groups

    bmh = jnp.repeat(bm, hpg, axis=1)  # (B, H, N)
    cmh = jnp.repeat(cm, hpg, axis=1)
    hnew = decay[..., None, None] * state.h + (dt[..., None] * bmh)[..., None] * xh[:, :, None, :]
    y = jnp.einsum("bhn,bhnp->bhp", cmh, hnew) + p["D"][None, :, None] * xh

    y = y.reshape(b, c.d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = (y @ L_W(p["wo"]).astype(x.dtype))[:, None, :]
    return out, SSMState(h=hnew, conv_x=nconv_x, conv_B=nconv_B, conv_C=nconv_C)

"""Uniform model API over all families.

``Model(cfg)`` exposes:
  param_descs()                  -> descriptor tree
  loss(params, batch)            -> scalar          (train shapes)
  forward(params, batch)         -> logits          (prefill shapes)
  cache_descs(batch, cache_len)  -> cache descriptor tree
  decode(params, cache, batch)   -> (logits, cache) (decode shapes)
  input_descs(shape)             -> batch descriptor tree (ParamDesc leaves,
                                    so the dry-run derives ShapeDtypeStructs
                                    AND PartitionSpecs from one source)
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec, hybrid, mamba_lm, transformer
from repro.models.base import ParamDesc


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig

    # -- params ----------------------------------------------------------
    def param_descs(self):
        f = self.cfg.family
        if f in ("dense", "moe", "vlm"):
            return transformer.lm_descs(self.cfg)
        if f == "ssm":
            return mamba_lm.mamba_descs(self.cfg)
        if f == "hybrid":
            return hybrid.hybrid_descs(self.cfg)
        if f == "encdec":
            return encdec.encdec_descs(self.cfg)
        raise ValueError(f"unknown family {f}")

    # -- train -----------------------------------------------------------
    def loss(self, params, batch):
        f = self.cfg.family
        if f in ("dense", "moe", "vlm"):
            return transformer.lm_loss(params, self.cfg, batch)
        if f == "ssm":
            return mamba_lm.mamba_loss(params, self.cfg, batch)
        if f == "hybrid":
            return hybrid.hybrid_loss(params, self.cfg, batch)
        if f == "encdec":
            return encdec.encdec_loss(params, self.cfg, batch)
        raise ValueError(f)

    # -- prefill ---------------------------------------------------------
    def forward(self, params, batch):
        f = self.cfg.family
        if f in ("dense", "moe", "vlm"):
            return transformer.lm_forward(
                params, self.cfg, batch["tokens"], batch.get("vision_embeds")
            )[0]
        if f == "ssm":
            return mamba_lm.mamba_forward(params, self.cfg, batch["tokens"])[0]
        if f == "hybrid":
            return hybrid.hybrid_forward(params, self.cfg, batch["tokens"])[0]
        if f == "encdec":
            return encdec.encdec_forward(
                params, self.cfg, batch["frames"], batch["tokens"]
            )[0]
        raise ValueError(f)

    # -- decode ----------------------------------------------------------
    def cache_descs(self, batch: int, cache_len: int):
        f = self.cfg.family
        if f in ("dense", "moe", "vlm"):
            return transformer.lm_cache_descs(self.cfg, batch, cache_len)
        if f == "ssm":
            return mamba_lm.mamba_cache_descs(self.cfg, batch, cache_len)
        if f == "hybrid":
            return hybrid.hybrid_cache_descs(self.cfg, batch, cache_len)
        if f == "encdec":
            return encdec.encdec_cache_descs(self.cfg, batch, cache_len)
        raise ValueError(f)

    def decode(self, params, cache, batch):
        f = self.cfg.family
        tokens = batch["tokens"]
        active = batch.get("active")  # (B,) live-slot mask: continuous batching
        tiers = batch.get("tiers")    # (B,) per-slot quality-tier indices
        demand = batch.get("demand")  # static plane-demand floor (python int)
        if f in ("dense", "moe", "vlm"):
            return transformer.lm_decode(params, self.cfg, cache, tokens,
                                         active=active, tiers=tiers,
                                         demand=demand)
        if active is not None or tiers is not None or demand is not None:
            raise ValueError(
                f"per-slot active masks / quality tiers (continuous "
                f"batching) are only supported by attention families, "
                f"not {f!r}"
            )
        if f == "ssm":
            return mamba_lm.mamba_decode(params, self.cfg, cache, tokens)
        if f == "hybrid":
            return hybrid.hybrid_decode(params, self.cfg, cache, tokens)
        if f == "encdec":
            return encdec.encdec_decode(params, self.cfg, cache, tokens)
        raise ValueError(f)

    def verify(self, params, cache, batch):
        """Batched multi-position forward for self-speculative verify:
        score each lane's drafted window ``[start, start+wlen)`` in one
        dispatch at the lane's verify tier, overwriting the draft-tier KV
        the draft ticks left in the cache.  Returns (logits (B, W, V),
        cache).  Attention families with full-length caches only — the
        same per-lane KV isolation admission relies on."""
        f = self.cfg.family
        if f not in ("dense", "moe", "vlm"):
            raise ValueError(
                f"speculative verify needs an attention family with "
                f"per-lane KV isolation, not {f!r}"
            )
        return transformer.lm_verify(
            params, self.cfg, cache, batch["tokens"], batch["start"],
            batch["wlen"], batch["spec"], tiers=batch.get("tiers"),
            demand=batch.get("demand"),
        )

    def prefill(self, params, cache, tokens, lengths=None, tiers=None,
                demand=None):
        """Prime a decode cache for whole (B, S) left-padded prompts.

        Attention families run ONE full-sequence causal forward (packed
        weights stream once per prompt); recurrent/cross families scan per
        token.  ``lengths`` (B,) is the real token count per slot — left
        padding beyond it is masked out of the KV cache.  Defaults to
        "no padding" (every slot length S).  ``tiers`` (B,) primes each
        slot at its own quality tier (per-row plane masks on packed
        weights; attention families only).  ``demand`` (static python int)
        is the batch plane-demand floor: packed plane-major weights only
        stream the planes some slot's tier keeps.  Returns
        (cache, last_logits).
        ``params`` may be any WeightStore mix — dense arrays, QSQ levels,
        or packed bit-planes."""
        from repro.train.step import make_cache_prefill_step

        if lengths is None:
            lengths = jnp.full((tokens.shape[0],), tokens.shape[1], jnp.int32)
        return make_cache_prefill_step(self)(params, cache, tokens, lengths,
                                             tiers, demand)

    def cache_insert_slot(self, live, one, slot):
        """Write a single-slot prefilled cache into lane ``slot`` of a live
        multi-slot cache — the continuous-batching admission primitive.
        Attention-family only (recurrent state has no per-lane isolation
        the scheduler could rely on)."""
        from repro.train.step import supports_fused_prefill

        if not supports_fused_prefill(self):
            raise ValueError(
                f"single-slot cache admission needs an attention family "
                f"with per-lane KV isolation; family {self.cfg.family!r} "
                f"(cross_every={self.cfg.cross_every}) is served via the "
                f"static batch path"
            )
        return transformer.lm_cache_insert_slot(live, one, slot)

    def serve_params(self, wire_tree, packed: bool = True, drop_map=None,
                     tier_drop_map=None):
        """Wire artifact -> serving param tree (packed matmul weights when
        ``packed``, full dense decode otherwise).  Returns (params, n_packed).

        ``drop_map`` (path -> LSB planes to drop) realizes a quality tier on
        the already-quantized codes — the EdgeArtifact dial — without ever
        re-quantizing.  ``tier_drop_map`` (path -> per-tier drop vector)
        instead keeps full-quality planes and stamps the vector on each
        packed leaf for PER-REQUEST tier masking at matmul time (packed
        serving only)."""
        from repro.models.base import abstract_params
        from repro.quant.store import dense_tree, serve_tree, tree_from_wire, truncate_tree

        store = tree_from_wire(wire_tree)
        descs = self.param_descs()
        if packed:
            return serve_tree(store, descs, drop_map=drop_map,
                              tier_drop_map=tier_drop_map)
        if tier_drop_map:
            raise ValueError(
                "per-request tier vectors need packed serving (the masks "
                "apply inside the fused kernel's unpack)"
            )
        if drop_map:
            store = truncate_tree(store, drop_map)
        # qsqlint: disable=QSQ001 -- the explicit packed=False opt-out:
        # caller asked for full dense decode at load time, once
        return dense_tree(store, like=abstract_params(descs)), 0

    # -- inputs ----------------------------------------------------------
    def input_descs(self, shape: ShapeConfig):
        cfg = self.cfg
        b = shape.global_batch
        def tok(s):
            return ParamDesc((b, s), ("batch", None), dtype=jnp.int32,
                             init="zeros")

        if shape.kind == "train":
            batch = {"tokens": tok(shape.seq_len), "labels": tok(shape.seq_len)}
        elif shape.kind == "prefill":
            batch = {"tokens": tok(shape.seq_len)}
        else:  # decode: one new token; the context length lives in the cache
            batch = {"tokens": tok(1)}
        if cfg.family == "vlm" and shape.kind in ("train", "prefill"):
            batch["vision_embeds"] = ParamDesc(
                (b, cfg.vision_tokens, cfg.d_model), ("batch", None, None),
                dtype=cfg.dtype, init="normal",
            )
        if cfg.family == "encdec" and shape.kind in ("train", "prefill"):
            batch["frames"] = ParamDesc(
                (b, cfg.enc_seq, cfg.d_model), ("batch", None, None),
                dtype=cfg.dtype, init="normal",
            )
        return batch

"""Model zoo: dense/MoE/VLM transformers, Mamba2, Jamba hybrid, Whisper
enc-dec, and the paper's LeNet/ConvNet."""
from repro.models import base, cnn, encdec, hybrid, layers, mamba_lm, ssm, transformer
from repro.models.api import Model

__all__ = ["Model", "base", "layers", "ssm", "transformer", "hybrid",
           "mamba_lm", "encdec", "cnn"]

"""Model zoo: dense/MoE/VLM transformers, Mamba2, Jamba hybrid, Whisper
enc-dec, and the paper's LeNet/ConvNet."""
from repro.models.api import Model
from repro.models import base, layers, ssm, transformer, hybrid, mamba_lm, encdec, cnn

__all__ = ["Model", "base", "layers", "ssm", "transformer", "hybrid",
           "mamba_lm", "encdec", "cnn"]

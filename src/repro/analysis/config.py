"""qsqlint configuration: rule selection, per-rule knobs, allowlists.

Defaults are the repo's own contracts (hot-path packages, the dispatch
counter objects, the static-arg discipline names).  Projects can override
any key from ``[tool.qsqlint]`` in ``pyproject.toml`` (read when a TOML
parser is available — py3.11's ``tomllib``; silently skipped otherwise so
the linter has zero hard deps) or from a JSON file via ``--config``.

Allowlist entries are strings ``"RULE:path-glob"`` or
``"RULE:path-glob:qualname"`` — a violation of RULE inside a matching
file (and, when given, inside the named function scope) is suppressed
without an inline pragma.  Pragmas are preferred for one-off exemptions
(they sit next to the code and carry a justification); the allowlist is
for structural ones, like the dispatch module's own counter helpers.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
from pathlib import Path

#: Rules every run enables unless --select/--ignore narrows them.
ALL_RULES = ("QSQ001", "QSQ002", "QSQ003", "QSQ004", "QSQ005")

_DEFAULTS: dict = {
    # QSQ001: packages where a dense-materializing call is a hot-path bug
    "hot_paths": [
        "src/repro/serve",
        "src/repro/models",
        "src/repro/kernels",
    ],
    # QSQ001: call names that materialize a dense weight from a store leaf
    "dense_calls": ["as_dense", "dequantize", "dense_tree"],
    # QSQ002/QSQ003: parameter names that must be static jit args wherever
    # the function carrying them is jitted (plane demand and friends: a
    # traced demand would turn every shortened HBM read into a retrace or
    # a tracer leak)
    "static_params": [
        "demand",
        "demand_tier",
        "demand_drop",
        "drop",
        "plane_major",
        "sign_mag",
    ],
    # QSQ003: parameter names that must NEVER be static — they are traced
    # by design, so that tier changes / admissions are data changes (mask
    # flips), not retraces
    "never_static": ["plane_mask", "tiers", "active"],
    # QSQ002: callables whose first argument is traced like a jitted body
    "scan_callees": ["jax.lax.scan", "repro.models.base.xscan"],
    # QSQ005: the trace-time counter objects, fully qualified
    "counter_objects": [
        "repro.kernels.dispatch.counters",
        "repro.kernels.dispatch.traffic",
    ],
    # QSQ005: the only scopes allowed to mutate them ("path::qualname";
    # "<module>" is module level, for the defining assignments)
    "counter_scopes": [
        "src/repro/kernels/dispatch.py::<module>",
        "src/repro/kernels/dispatch.py::packed_matmul",
        "src/repro/kernels/dispatch.py::_count_traffic",
        "src/repro/kernels/dispatch.py::reset_counters",
    ],
    # global allowlist entries: "RULE:path-glob[:qualname]"
    "allow": [],
}


@dataclasses.dataclass(frozen=True)
class Config:
    """Resolved qsqlint configuration (immutable; see module docstring)."""

    select: tuple[str, ...] = ALL_RULES
    hot_paths: tuple[str, ...] = tuple(_DEFAULTS["hot_paths"])
    dense_calls: tuple[str, ...] = tuple(_DEFAULTS["dense_calls"])
    static_params: tuple[str, ...] = tuple(_DEFAULTS["static_params"])
    never_static: tuple[str, ...] = tuple(_DEFAULTS["never_static"])
    scan_callees: tuple[str, ...] = tuple(_DEFAULTS["scan_callees"])
    counter_objects: tuple[str, ...] = tuple(_DEFAULTS["counter_objects"])
    counter_scopes: tuple[str, ...] = tuple(_DEFAULTS["counter_scopes"])
    allow: tuple[str, ...] = ()

    def replace(self, **kw) -> "Config":
        return dataclasses.replace(self, **kw)

    # -- queries the rules ask ---------------------------------------------
    def is_hot_path(self, path: str) -> bool:
        p = path.replace("\\", "/")
        return any(
            p == hp or p.startswith(hp.rstrip("/") + "/")
            for hp in self.hot_paths
        )

    def counter_scope_allowed(self, path: str, qualname: str) -> bool:
        key = f"{path}::{qualname}"
        return any(fnmatch.fnmatch(key, pat) for pat in self.counter_scopes)

    def allowlisted(self, rule: str, path: str, qualname: str) -> bool:
        for entry in self.allow:
            parts = entry.split(":")
            if len(parts) < 2 or parts[0] != rule:
                continue
            glob, func = parts[1], (parts[2] if len(parts) > 2 else None)
            if not fnmatch.fnmatch(path, glob):
                continue
            if func is None or func == qualname or qualname.endswith("." + func):
                return True
        return False


def _merge(base: Config, overrides: dict) -> Config:
    known = {f.name for f in dataclasses.fields(Config)}
    kw = {}
    for key, val in overrides.items():
        name = key.replace("-", "_")
        if name not in known:
            raise KeyError(f"unknown qsqlint config key {key!r}")
        kw[name] = tuple(val) if isinstance(val, (list, tuple)) else val
    return base.replace(**kw)


def _pyproject_overrides(root: Path) -> dict:
    pyproject = root / "pyproject.toml"
    if not pyproject.is_file():
        return {}
    try:
        import tomllib  # py >= 3.11
    except ImportError:
        return {}
    with open(pyproject, "rb") as f:
        data = tomllib.load(f)
    return data.get("tool", {}).get("qsqlint", {})


def load_config(root: str | Path = ".", config_file: str | Path | None = None,
                overrides: dict | None = None) -> Config:
    """Resolve the effective Config for a lint run rooted at ``root``.

    Precedence: built-in defaults < ``[tool.qsqlint]`` in pyproject.toml
    < ``config_file`` (JSON) < ``overrides`` (programmatic / CLI flags).
    """
    cfg = Config(allow=tuple(_DEFAULTS["allow"]))
    cfg = _merge(cfg, _pyproject_overrides(Path(root)))
    if config_file is not None:
        with open(config_file) as f:
            cfg = _merge(cfg, json.load(f))
    if overrides:
        cfg = _merge(cfg, overrides)
    return cfg

"""qsqlint core: file loading, pragmas, the project pass, reporting.

A lint run is two passes.  Pass one parses every file and builds a
:class:`ProjectIndex` — cross-file facts, today the step-factory table
(QSQ003 must connect ``jax.jit(make_cont_decode_step(model), ...)`` in
``serve/engine.py`` to the factory's inner signature in
``train/step.py``).  Pass two runs every enabled rule per file and
filters the findings through inline pragmas and the config allowlist.

Pragma syntax (trailing comment on the flagged line)::

    planes = p.as_dense()  # qsqlint: disable=QSQ001 -- cold path: <why>
    # qsqlint: disable-file=QSQ002 -- whole-file suppression

Multiple rules separate with commas; ``all`` disables everything.  The
`` -- why`` justification is free text; keep one — a bare pragma reads
as a silenced alarm, a justified one as a reviewed exemption.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import re
import tokenize
from pathlib import Path

from repro.analysis.astutil import ModuleAnalysis
from repro.analysis.config import Config

PRAGMA_RE = re.compile(
    r"#\s*qsqlint:\s*(?P<kind>disable(?:-file)?)\s*=\s*(?P<rules>[A-Za-z0-9_,\s]+?)"
    r"\s*(?:--.*)?$"
)

_ALL = "all"


@dataclasses.dataclass(frozen=True)
class Violation:
    """One finding: where, which rule, why it matters."""

    path: str
    line: int
    col: int
    rule: str
    message: str
    qualname: str = "<module>"  # enclosing function scope, for allowlists

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


@dataclasses.dataclass
class Pragmas:
    file_rules: set[str]
    line_rules: dict[int, set[str]]

    def suppressed(self, rule: str, line: int) -> bool:
        for rules in (self.file_rules, self.line_rules.get(line, ())):
            if _ALL in rules or rule in rules:
                return True
        return False


def parse_pragmas(source: str) -> Pragmas:
    """Trailing pragmas suppress their own line; a pragma on a
    comment-only line suppresses the next code line (so multi-line
    justifications above a statement work)."""
    pragmas = Pragmas(file_rules=set(), line_rules={})
    lines = source.splitlines()
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        comments = [(t.start[0], t.string) for t in tokens
                    if t.type == tokenize.COMMENT]
    except tokenize.TokenError:
        comments = [(i + 1, line.strip()) for i, line in
                    enumerate(lines) if "#" in line]

    def _attach_line(lineno: int) -> int:
        # standalone comment: walk down past comments/blanks to the code line
        if not lines[lineno - 1].lstrip().startswith("#"):
            return lineno
        at = lineno
        while at < len(lines):
            stripped = lines[at].strip()  # 0-based `at` is the NEXT line
            if stripped and not stripped.startswith("#"):
                return at + 1
            at += 1
        return lineno

    for lineno, text in comments:
        m = PRAGMA_RE.search(text)
        if not m:
            continue
        rules = {r.strip() for r in m.group("rules").split(",") if r.strip()}
        if m.group("kind") == "disable-file":
            pragmas.file_rules |= rules
        else:
            pragmas.line_rules.setdefault(
                _attach_line(lineno), set()).update(rules)
    return pragmas


@dataclasses.dataclass
class FileContext:
    """Everything a rule may consult about one file under lint."""

    path: str          # repo-relative posix path (display + config matching)
    source: str
    tree: ast.Module
    analysis: ModuleAnalysis
    pragmas: Pragmas
    config: Config
    index: "ProjectIndex"


class ProjectIndex:
    """Cross-file facts, built before any rule runs."""

    def __init__(self):
        # canonical factory name -> FactoryInfo (also indexed by bare name
        # when unambiguous, for same-project resolution across modules)
        self.factories: dict[str, object] = {}
        self._by_bare: dict[str, list] = {}
        # every jax.jit(make_x(...)) site across the project — QSQ002 uses
        # these to treat a factory's inner def as jitted even when the jit
        # lives in another file (engine.py jits step.py's products)
        self.all_factory_jit_sites: list = []

    def add_module(self, analysis: ModuleAnalysis) -> None:
        for info in analysis.factories.values():
            self.factories[f"{info.module}.{info.name}"] = info
            self._by_bare.setdefault(info.name, []).append(info)
        self.all_factory_jit_sites.extend(analysis.factory_jit_sites)

    def find_factory(self, canonical_name: str):
        info = self.factories.get(canonical_name)
        if info is not None:
            return info
        candidates = self._by_bare.get(canonical_name.rsplit(".", 1)[-1], [])
        return candidates[0] if len(candidates) == 1 else None


def module_dotted(path: str) -> str:
    """Best-effort dotted module path from a repo-relative file path."""
    p = Path(path)
    parts = list(p.with_suffix("").parts)
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts) or "<unknown>"


def iter_python_files(paths: list[str | Path], root: Path) -> list[Path]:
    files: list[Path] = []
    for p in paths:
        p = (root / p) if not Path(p).is_absolute() else Path(p)
        if p.is_dir():
            files.extend(sorted(
                f for f in p.rglob("*.py") if "__pycache__" not in f.parts
            ))
        elif p.suffix == ".py":
            files.append(p)
    return files


def _display_path(file: Path, root: Path) -> str:
    try:
        return file.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        return file.as_posix()


def _build_context(file: Path, root: Path, config: Config,
                   index: ProjectIndex) -> FileContext | Violation:
    rel = _display_path(file, root)
    source = file.read_text()
    try:
        tree = ast.parse(source, filename=str(file))
    except SyntaxError as e:
        return Violation(path=rel, line=e.lineno or 1, col=e.offset or 0,
                         rule="QSQ000", message=f"syntax error: {e.msg}")
    analysis = ModuleAnalysis(tree, rel, module_dotted(rel),
                              scan_callees=config.scan_callees)
    return FileContext(path=rel, source=source, tree=tree, analysis=analysis,
                       pragmas=parse_pragmas(source), config=config,
                       index=index)


def lint_paths(paths: list[str | Path], config: Config | None = None,
               root: str | Path = ".") -> list[Violation]:
    """Lint every .py under ``paths`` (files or directories) and return
    surviving violations, sorted by (path, line, col, rule)."""
    from repro.analysis.rules import RULES

    config = config or Config()
    root = Path(root)
    contexts: list[FileContext] = []
    violations: list[Violation] = []
    index = ProjectIndex()
    for file in iter_python_files(paths, root):
        ctx = _build_context(file, root, config, index)
        if isinstance(ctx, Violation):
            violations.append(ctx)
            continue
        index.add_module(ctx.analysis)
        contexts.append(ctx)

    enabled = [RULES[r] for r in config.select if r in RULES]
    seen: set[Violation] = set()
    for ctx in contexts:
        for rule in enabled:
            for v in rule().check(ctx):
                if v in seen:  # e.g. twin factory inners, same site+message
                    continue
                if ctx.pragmas.suppressed(v.rule, v.line):
                    continue
                if config.allowlisted(v.rule, v.path, v.qualname):
                    continue
                seen.add(v)
                violations.append(v)
    violations.sort(key=lambda v: (v.path, v.line, v.col, v.rule))
    return violations


def lint_file(path: str | Path, config: Config | None = None,
              root: str | Path = ".") -> list[Violation]:
    """Single-file convenience wrapper over :func:`lint_paths`."""
    return lint_paths([path], config=config, root=root)

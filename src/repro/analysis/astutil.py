"""AST groundwork shared by the qsqlint rules.

One :class:`ModuleAnalysis` is built per file and answers the questions
every rule asks:

* alias resolution — ``jnp.dot`` -> ``jax.numpy.dot`` via the module's
  imports, so rules match canonical dotted names, not spelling;
* scopes — a binding tree (module / function / lambda / comprehension)
  with name resolution up the enclosing chain;
* jit contexts — which function defs run under trace: decorator-jitted
  (``@jax.jit`` / ``@functools.partial(jax.jit, ...)``), call-site-jitted
  (``f = jax.jit(g, ...)``), scan bodies, and — via the cross-file
  project index — the inner functions of jitted step FACTORIES
  (``jax.jit(make_cont_decode_step(model), static_argnums=(5,))``);
* static-argument resolution — ``static_argnums``/``static_argnames`` of
  a jit site mapped onto the jitted function's parameter names;
* factories — defs that ``return`` a locally defined function, with that
  inner function's parameter list (the shape QSQ003 checks against);
* Pallas kernels — defs reaching ``pl.pallas_call`` as the kernel
  operand, directly or through a ``functools.partial`` binding.

Everything here is deliberately flow-light: a single forward walk per
function, no fixpoints.  Lint rules prefer a small number of
well-understood checks over exhaustive dataflow.
"""
from __future__ import annotations

import ast
import builtins
import dataclasses

#: attribute names whose access on a tracer yields a STATIC value — a
#: Python branch on these is trace-time shape logic, not a tracer leak.
STATIC_ATTRS = frozenset({"shape", "ndim", "dtype", "size", "sharding"})

#: calls that collapse a traced operand to a static value (len(x) is
#: x.shape[0]; isinstance/type dispatch on the tracer object itself).
STATIC_CALLS = frozenset({"len", "isinstance", "type", "getattr", "hasattr"})

JIT_NAMES = frozenset({"jax.jit", "jax.pjit", "jax.experimental.pjit.pjit"})

PALLAS_CALL = "jax.experimental.pallas.pallas_call"
BLOCKSPEC_CALLS = frozenset({
    "jax.experimental.pallas.BlockSpec",
    "jax.experimental.pallas.tpu.VMEM",
    "jax.experimental.pallas.tpu.SMEM",
})

#: module prefixes whose array constructors must not be closure-captured
#: by a kernel body (a captured device array becomes an invisible kernel
#: operand the BlockSpecs know nothing about).
ARRAY_MODULES = ("jax.numpy.", "numpy.", "jax.random.")


# --------------------------------------------------------------------------
# Aliases
# --------------------------------------------------------------------------
def build_aliases(tree: ast.Module) -> dict[str, str]:
    """Map local names to canonical dotted paths from the module's imports."""
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    aliases[a.asname] = a.name
                else:
                    root = a.name.split(".")[0]
                    aliases[root] = root
        elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
            for a in node.names:
                if a.name == "*":
                    continue
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def dotted(node: ast.AST, aliases: dict[str, str]) -> str | None:
    """Canonical dotted path of a Name/Attribute chain, alias-expanded."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(aliases.get(node.id, node.id))
    return ".".join(reversed(parts))


# --------------------------------------------------------------------------
# Scopes
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Scope:
    node: ast.AST  # Module | FunctionDef | AsyncFunctionDef | Lambda
    parent: "Scope | None"
    qualname: str
    bindings: dict[str, ast.AST] = dataclasses.field(default_factory=dict)

    def resolve(self, name: str) -> "tuple[Scope, ast.AST] | None":
        scope: Scope | None = self
        while scope is not None:
            if name in scope.bindings:
                return scope, scope.bindings[name]
            scope = scope.parent
        return None


def _bind_target(scope: Scope, target: ast.AST, value: ast.AST) -> None:
    if isinstance(target, ast.Name):
        scope.bindings[target.id] = value
    elif isinstance(target, (ast.Tuple, ast.List)):
        for elt in target.elts:
            _bind_target(scope, elt, value)
    elif isinstance(target, ast.Starred):
        _bind_target(scope, target.value, value)


class _ScopeBuilder(ast.NodeVisitor):
    """Build the scope tree; record the scope owning every function def."""

    def __init__(self, tree: ast.Module):
        self.module_scope = Scope(tree, None, "<module>")
        self.fn_scopes: dict[ast.AST, Scope] = {}
        self.fn_parent: dict[ast.AST, Scope] = {}
        self._stack = [self.module_scope]
        self.visit(tree)

    @property
    def _cur(self) -> Scope:
        return self._stack[-1]

    def _visit_function(self, node):
        self.fn_parent[node] = self._cur
        self._cur.bindings[node.name] = node
        qual = (node.name if self._cur.qualname == "<module>"
                else f"{self._cur.qualname}.{node.name}")
        scope = Scope(node, self._cur, qual)
        for arg in _all_args(node.args):
            scope.bindings[arg] = node
        self.fn_scopes[node] = scope
        self._stack.append(scope)
        for stmt in node.body:
            self.visit(stmt)
        self._stack.pop()

    visit_FunctionDef = _visit_function
    visit_AsyncFunctionDef = _visit_function

    def visit_Lambda(self, node: ast.Lambda):
        scope = Scope(node, self._cur, f"{self._cur.qualname}.<lambda>")
        for arg in _all_args(node.args):
            scope.bindings[arg] = node
        self.fn_scopes[node] = scope
        self._stack.append(scope)
        self.visit(node.body)
        self._stack.pop()

    def visit_ClassDef(self, node: ast.ClassDef):
        self._cur.bindings[node.name] = node
        # class bodies are not enclosing scopes for the methods inside
        # them; keep walking in the current scope chain (close enough for
        # the repo's method-light modules)
        for stmt in node.body:
            self.visit(stmt)

    def visit_Assign(self, node: ast.Assign):
        for t in node.targets:
            _bind_target(self._cur, t, node.value)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign):
        if node.value is not None:
            _bind_target(self._cur, node.target, node.value)
        self.generic_visit(node)

    def visit_NamedExpr(self, node: ast.NamedExpr):
        _bind_target(self._cur, node.target, node.value)
        self.generic_visit(node)

    def visit_For(self, node: ast.For):
        _bind_target(self._cur, node.target, node.iter)
        self.generic_visit(node)

    def visit_With(self, node: ast.With):
        for item in node.items:
            if item.optional_vars is not None:
                _bind_target(self._cur, item.optional_vars, item.context_expr)
        self.generic_visit(node)

    def visit_comprehension(self, node: ast.comprehension):
        _bind_target(self._cur, node.target, node.iter)
        self.generic_visit(node)

    def visit_Import(self, node: ast.Import):
        for a in node.names:
            self._cur.bindings[a.asname or a.name.split(".")[0]] = node

    def visit_ImportFrom(self, node: ast.ImportFrom):
        for a in node.names:
            if a.name != "*":
                self._cur.bindings[a.asname or a.name] = node


def _all_args(args: ast.arguments) -> list[str]:
    names = [a.arg for a in args.posonlyargs + args.args + args.kwonlyargs]
    if args.vararg:
        names.append(args.vararg.arg)
    if args.kwarg:
        names.append(args.kwarg.arg)
    return names


def positional_params(args: ast.arguments) -> list[str]:
    return [a.arg for a in args.posonlyargs + args.args]


# --------------------------------------------------------------------------
# Jit contexts, factories, kernels
# --------------------------------------------------------------------------
@dataclasses.dataclass
class JitContext:
    fn: ast.AST  # FunctionDef
    static_names: frozenset[str]
    reason: str  # "decorator" | "jit-call" | "scan-body" | "factory-inner"


@dataclasses.dataclass
class FactoryInfo:
    """A def that returns a locally defined function (a step factory)."""

    module: str  # dotted module path, e.g. "repro.train.step"
    path: str    # repo-relative file path
    name: str
    node: ast.AST
    inners: list[ast.AST]  # the returned FunctionDef nodes


@dataclasses.dataclass
class FactoryJitSite:
    """``jax.jit(make_x(...), static_arg...=...)`` — jitting a factory's
    product.  Resolved against FactoryInfo in the project pass."""

    callee: str  # canonical dotted name of the factory
    jit_call: ast.Call
    lineno: int
    col: int
    qualname: str  # enclosing scope at the jit site


def static_names_from_jit(keywords: list[ast.keyword],
                          params: list[str]) -> frozenset[str]:
    """Resolve static_argnums/static_argnames keywords to parameter names."""
    names: set[str] = set()
    for kw in keywords:
        if kw.arg == "static_argnames":
            for const in ast.walk(kw.value):
                if isinstance(const, ast.Constant) and isinstance(const.value, str):
                    names.add(const.value)
        elif kw.arg == "static_argnums":
            for const in ast.walk(kw.value):
                if isinstance(const, ast.Constant) and isinstance(const.value, int):
                    if 0 <= const.value < len(params):
                        names.add(params[const.value])
    return frozenset(names)


def jit_decorator_statics(fn, aliases) -> frozenset[str] | None:
    """Static names if ``fn`` is decorator-jitted, else None."""
    for dec in fn.decorator_list:
        if dotted(dec, aliases) in JIT_NAMES:
            return frozenset()
        if isinstance(dec, ast.Call):
            callee = dotted(dec.func, aliases)
            if callee in JIT_NAMES:
                return static_names_from_jit(
                    dec.keywords, positional_params(fn.args))
            if (callee == "functools.partial" and dec.args
                    and dotted(dec.args[0], aliases) in JIT_NAMES):
                return static_names_from_jit(
                    dec.keywords, positional_params(fn.args))
    return None


class ModuleAnalysis:
    """Everything the rules need to know about one parsed module."""

    def __init__(self, tree: ast.Module, path: str, module: str,
                 scan_callees: tuple[str, ...] = ()):
        self.tree = tree
        self.path = path
        self.module = module
        self.aliases = build_aliases(tree)
        builder = _ScopeBuilder(tree)
        self.module_scope = builder.module_scope
        self.fn_scopes = builder.fn_scopes
        self.fn_parent = builder.fn_parent
        self.parent_map: dict[ast.AST, ast.AST] = {}
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                self.parent_map[child] = node

        self.jit_contexts: dict[ast.AST, JitContext] = {}
        self.factories: dict[str, FactoryInfo] = {}
        self.factory_jit_sites: list[FactoryJitSite] = []
        self.kernels: dict[ast.AST, ast.Call] = {}  # kernel def -> call site
        self._collect_factories()
        self._collect_jit_contexts(scan_callees)
        self._collect_kernels()

    # -- helpers -----------------------------------------------------------
    def qualname_of(self, node: ast.AST) -> str:
        """Qualified name of the function scope enclosing ``node``."""
        cur = node
        while cur is not None:
            if cur in self.fn_scopes:
                return self.fn_scopes[cur].qualname
            cur = self.parent_map.get(cur)
        return "<module>"

    def enclosing_scope(self, node: ast.AST) -> Scope:
        cur = self.parent_map.get(node)
        while cur is not None:
            if cur in self.fn_scopes:
                return self.fn_scopes[cur]
            cur = self.parent_map.get(cur)
        return self.module_scope

    def resolve_def(self, name: str, at: ast.AST):
        """Resolve ``name`` to a FunctionDef through the scope chain."""
        hit = self.enclosing_scope(at).resolve(name)
        if hit is None:
            return None
        _, bound = hit
        return bound if isinstance(bound, (ast.FunctionDef,
                                           ast.AsyncFunctionDef)) else None

    def canonical(self, node: ast.AST) -> str | None:
        """Dotted path of an expression, module-qualified when local."""
        name = dotted(node, self.aliases)
        if name is None:
            return None
        if "." not in name and name not in self.aliases:
            return f"{self.module}.{name}"
        return name

    # -- collection passes -------------------------------------------------
    def _collect_factories(self) -> None:
        for fn, scope in list(self.fn_scopes.items()):
            if isinstance(fn, ast.Lambda):
                continue
            inners = []
            for stmt in ast.walk(fn):
                if (isinstance(stmt, ast.Return)
                        and isinstance(stmt.value, ast.Name)):
                    bound = scope.bindings.get(stmt.value.id)
                    if isinstance(bound, (ast.FunctionDef,
                                          ast.AsyncFunctionDef)):
                        inners.append(bound)
            if inners and self.fn_parent[fn] is self.module_scope:
                self.factories[fn.name] = FactoryInfo(
                    module=self.module, path=self.path, name=fn.name,
                    node=fn, inners=inners)

    def _add_jit(self, fn: ast.AST, statics: frozenset[str], reason: str):
        prev = self.jit_contexts.get(fn)
        if prev is not None:
            statics = statics | prev.static_names
        self.jit_contexts[fn] = JitContext(fn, statics, reason)

    def _collect_jit_contexts(self, scan_callees: tuple[str, ...]) -> None:
        for fn in self.fn_scopes:
            if isinstance(fn, ast.Lambda):
                continue
            statics = jit_decorator_statics(fn, self.aliases)
            if statics is not None:
                self._add_jit(fn, statics, "decorator")
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            callee = dotted(node.func, self.aliases)
            if callee in JIT_NAMES and node.args:
                target = node.args[0]
                if isinstance(target, ast.Name):
                    fn = self.resolve_def(target.id, node)
                    if fn is not None:
                        self._add_jit(fn, static_names_from_jit(
                            node.keywords, positional_params(fn.args)),
                            "jit-call")
                elif isinstance(target, ast.Call):
                    factory = self.canonical(target.func)
                    if factory is not None:
                        self.factory_jit_sites.append(FactoryJitSite(
                            callee=factory, jit_call=node,
                            lineno=node.lineno, col=node.col_offset,
                            qualname=self.qualname_of(node)))
            elif callee in scan_callees and node.args:
                target = node.args[0]
                if isinstance(target, ast.Name):
                    fn = self.resolve_def(target.id, node)
                    if fn is not None:
                        self._add_jit(fn, frozenset(), "scan-body")

    def _resolve_kernel_operand(self, operand: ast.AST, at: ast.AST):
        """The kernel FunctionDef behind a pallas_call operand: a direct
        name, an inline functools.partial, or a name bound to one."""
        if isinstance(operand, ast.Call):
            if (dotted(operand.func, self.aliases) == "functools.partial"
                    and operand.args and isinstance(operand.args[0], ast.Name)):
                return self.resolve_def(operand.args[0].id, at)
            return None
        if isinstance(operand, ast.Name):
            fn = self.resolve_def(operand.id, at)
            if fn is not None:
                return fn
            hit = self.enclosing_scope(at).resolve(operand.id)
            if hit is not None and isinstance(hit[1], ast.Call):
                return self._resolve_kernel_operand(hit[1], at)
        return None

    def _collect_kernels(self) -> None:
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            if dotted(node.func, self.aliases) != PALLAS_CALL or not node.args:
                continue
            fn = self._resolve_kernel_operand(node.args[0], node)
            if fn is not None:
                self.kernels.setdefault(fn, node)


# --------------------------------------------------------------------------
# Taint: does an expression depend on a traced value?
# --------------------------------------------------------------------------
def expr_taints(node: ast.AST, tainted: set[str]) -> bool:
    """True if ``node``'s value can depend on a tracer named in ``tainted``.

    Access through a STATIC_ATTRS attribute (``x.shape`` and friends) and
    identity-vs-None comparisons are static at trace time and do not
    propagate taint; neither do STATIC_CALLS.  Function/lambda bodies are
    opaque (their names don't leak taint by reference).
    """
    if isinstance(node, ast.Name):
        return node.id in tainted
    if isinstance(node, ast.Attribute):
        if node.attr in STATIC_ATTRS:
            return False
        return expr_taints(node.value, tainted)
    if isinstance(node, ast.Subscript):
        return (expr_taints(node.value, tainted)
                or expr_taints(node.slice, tainted))
    if isinstance(node, ast.Compare):
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            operands = [node.left, *node.comparators]
            if any(isinstance(o, ast.Constant) and o.value is None
                   for o in operands):
                return False
        return any(expr_taints(o, tainted)
                   for o in [node.left, *node.comparators])
    if isinstance(node, ast.Call):
        if isinstance(node.func, ast.Name) and node.func.id in STATIC_CALLS:
            return False
        parts = [node.func, *node.args, *[kw.value for kw in node.keywords]]
        return any(expr_taints(p, tainted) for p in parts)
    if isinstance(node, (ast.Lambda, ast.FunctionDef, ast.AsyncFunctionDef)):
        return False
    return any(expr_taints(child, tainted)
               for child in ast.iter_child_nodes(node))


def walk_expr(node: ast.AST):
    """Yield ``node`` and descendants, not descending into nested
    function/lambda bodies (they are separate trace scopes)."""
    yield node
    for child in ast.iter_child_nodes(node):
        if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
            continue
        yield from walk_expr(child)


def is_builtin(name: str) -> bool:
    return hasattr(builtins, name)

"""Runtime companion to qsqlint: assert no retrace / no counter drift.

qsqlint argues statically (QSQ002/QSQ003) that the decode programs trace
once and that demand is a static arg.  :func:`no_retrace` asserts the
same thing at run time: inside the block, no watched jitted function may
grow its compilation cache, and the dispatch trace counters must not
move.  The scheduler/per-request/plane-stream tests all share this via
the ``no_retrace`` fixture in ``tests/conftest.py`` instead of each
hand-rolling counter snapshots.

Usage::

    with no_retrace(eng._cont_step, eng._admit):
        for _ in range(32):
            eng.step()          # admits/evicts/steps freely

    with no_retrace(counters=False):   # cache checks only, w/o dispatch
        ...

Each watched function must expose ``_cache_size()`` (every ``jax.jit``
product does).
"""
from __future__ import annotations

import contextlib

from repro.kernels import dispatch


def _cache_sizes(fns) -> list[int]:
    sizes = []
    for fn in fns:
        probe = getattr(fn, "_cache_size", None)
        if probe is None:
            raise TypeError(
                f"no_retrace() watches jitted callables with _cache_size(); "
                f"got {fn!r}")
        sizes.append(probe())
    return sizes


@contextlib.contextmanager
def no_retrace(*jitted, counters: bool = True):
    """Assert that the enclosed block triggers no new traces.

    ``jitted``: jitted callables to watch — their ``_cache_size()`` must
    be unchanged on exit (zero new compilations).  ``counters``: also
    snapshot ``dispatch.counters``/``dispatch.traffic`` and require them
    unchanged — the kernel dispatcher bumps them once per trace, so any
    drift inside the block is a retrace (or a QSQ005 violation bumping
    them at run time).
    """
    before_sizes = _cache_sizes(jitted)
    if counters:
        before_counters = dict(dispatch.counters)
        before_traffic = dict(dispatch.traffic)
    yield
    after_sizes = _cache_sizes(jitted)
    for fn, before, after in zip(jitted, before_sizes, after_sizes,
                                 strict=True):
        if after != before:
            raise AssertionError(
                f"retrace detected: {getattr(fn, '__name__', fn)!r} "
                f"compilation cache grew {before} -> {after} inside a "
                f"no_retrace() block")
    if counters:
        now_counters = dict(dispatch.counters)
        now_traffic = dict(dispatch.traffic)
        if now_counters != before_counters:
            raise AssertionError(
                "dispatch.counters moved inside a no_retrace() block: "
                f"{before_counters} -> {now_counters} (a counter bump "
                "means a kernel was re-traced, or something mutates the "
                "counters at run time)")
        if now_traffic != before_traffic:
            raise AssertionError(
                "dispatch.traffic moved inside a no_retrace() block: "
                f"{before_traffic} -> {now_traffic}")

"""CLI entry point: ``python -m repro.analysis [paths...]``.

Exit codes: 0 clean, 1 violations found, 2 usage/config error.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis.config import ALL_RULES, load_config
from repro.analysis.linter import lint_paths


def _parse_args(argv):
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=("qsqlint — static analysis for jit/trace hygiene and "
                     "packed-weight invariants (QSQ001..QSQ005)"),
    )
    parser.add_argument(
        "paths", nargs="*", default=["src", "tests", "benchmarks"],
        help="files or directories to lint (default: src tests benchmarks)")
    parser.add_argument(
        "--select", metavar="RULES",
        help="comma-separated rule ids to run (default: all)")
    parser.add_argument(
        "--ignore", metavar="RULES",
        help="comma-separated rule ids to skip")
    parser.add_argument(
        "--config", metavar="FILE",
        help="JSON config file overriding [tool.qsqlint] / defaults")
    parser.add_argument(
        "--root", default=".",
        help="repo root for relative paths + config matching (default: .)")
    parser.add_argument(
        "--list-rules", action="store_true",
        help="print the rule table and exit")
    return parser.parse_args(argv)


def _list_rules() -> None:
    from repro.analysis.rules import RULES

    for rule_id in ALL_RULES:
        cls = RULES[rule_id]
        print(f"{rule_id}  {cls.name:<24} {cls.summary}")


def main(argv=None) -> int:
    args = _parse_args(argv if argv is not None else sys.argv[1:])
    if args.list_rules:
        _list_rules()
        return 0

    try:
        config = load_config(root=args.root, config_file=args.config)
        select = list(config.select)
        if args.select:
            select = [r.strip() for r in args.select.split(",") if r.strip()]
        if args.ignore:
            ignored = {r.strip() for r in args.ignore.split(",")}
            select = [r for r in select if r not in ignored]
        unknown = [r for r in select if r not in ALL_RULES]
        if unknown:
            print(f"qsqlint: unknown rule(s): {', '.join(unknown)}",
                  file=sys.stderr)
            return 2
        config = config.replace(select=tuple(select))
    except (OSError, KeyError, ValueError) as e:
        print(f"qsqlint: config error: {e}", file=sys.stderr)
        return 2

    violations = lint_paths(args.paths, config=config, root=args.root)
    for v in violations:
        print(v.format())
    if violations:
        print(f"qsqlint: {len(violations)} violation(s) in "
              f"{len({v.path for v in violations})} file(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

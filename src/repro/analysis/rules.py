"""The qsqlint rules: QSQ001..QSQ005.

Each rule protects one invariant the serving stack's measured wins
depend on (see README §Static analysis for the table):

* QSQ001 ``no-dense-hot-path`` — packed weights stay packed on serve/
  model/kernel paths; one ``as_dense()`` forfeits the 3.2-4.6x
  weight-HBM cut the fused dequant-matmul exists for.
* QSQ002 ``tracer-leak`` — jitted/scanned bodies must not coerce or
  branch on traced values; a leak either crashes at trace time or, via
  silent recompilation, turns every admit/evict into a retrace.
* QSQ003 ``static-arg-discipline`` — plane demand (and the other
  trace-shaping knobs) must be static jit args wherever threaded, and
  the mask-flip operands (``plane_mask``/``tiers``/``active``) must
  never be: tier changes are data, demand changes are bounded retraces.
* QSQ004 ``kernel-purity`` — Pallas kernel bodies take everything
  through refs or ``functools.partial`` statics, never closure-captured
  arrays; block/scratch shapes are static expressions.
* QSQ005 ``trace-time-counters`` — ``dispatch.counters``/``traffic``
  mutate only in the dispatch module's designated helpers (they count
  TRACES; a runtime mutation would desynchronize every no-retrace
  assertion built on them).

A rule is a class with ``id``/``name``/``summary`` and a ``check(ctx)``
generator; ``@register`` adds it to :data:`RULES`.
"""
from __future__ import annotations

import ast
from typing import Iterator

from repro.analysis.astutil import (
    ARRAY_MODULES,
    BLOCKSPEC_CALLS,
    JitContext,
    ModuleAnalysis,
    _all_args,
    dotted,
    expr_taints,
    is_builtin,
    positional_params,
    static_names_from_jit,
    walk_expr,
)
from repro.analysis.linter import FileContext, Violation

RULES: dict[str, type] = {}


def register(cls):
    RULES[cls.id] = cls
    return cls


class Rule:
    id = "QSQ000"
    name = "abstract"
    summary = ""

    def check(self, ctx: FileContext) -> Iterator[Violation]:
        raise NotImplementedError

    def violation(self, ctx: FileContext, node: ast.AST,
                  message: str) -> Violation:
        return Violation(
            path=ctx.path, line=node.lineno, col=node.col_offset,
            rule=self.id, message=message,
            qualname=ctx.analysis.qualname_of(node),
        )


# --------------------------------------------------------------------------
# QSQ001
# --------------------------------------------------------------------------
@register
class NoDenseHotPath(Rule):
    id = "QSQ001"
    name = "no-dense-hot-path"
    summary = ("dense-materializing calls (as_dense/dequantize/dense_tree) "
               "are forbidden inside serve/, models/, kernels/")

    def check(self, ctx: FileContext) -> Iterator[Violation]:
        if not ctx.config.is_hot_path(ctx.path):
            return
        dense = set(ctx.config.dense_calls)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            name = None
            if isinstance(func, ast.Attribute) and func.attr in dense:
                name = func.attr
            elif isinstance(func, ast.Name) and func.id in dense:
                name = func.id
            if name is not None:
                yield self.violation(
                    ctx, node,
                    f"`{name}()` materializes a dense weight on a hot path; "
                    f"route packed leaves through `.matmul()`/the dispatch "
                    f"kernels, or pragma with a justification if this path "
                    f"is provably cold",
                )


# --------------------------------------------------------------------------
# QSQ002
# --------------------------------------------------------------------------
class _TracedBodyChecker:
    """Single forward walk over one jitted/scanned function body with a
    name-level taint set (non-static parameters and everything derived
    from them, minus `.shape`-style static projections)."""

    def __init__(self, rule: Rule, ctx: FileContext, fn: ast.AST,
                 statics: frozenset[str]):
        self.rule = rule
        self.ctx = ctx
        self.fn = fn
        self.tainted: set[str] = {
            a for a in _all_args(fn.args) if a not in statics
        }
        self.violations: list[Violation] = []

    def run(self) -> list[Violation]:
        self._block(self.fn.body)
        return self.violations

    # -- statements --------------------------------------------------------
    def _block(self, stmts) -> None:
        for s in stmts:
            self._stmt(s)

    def _stmt(self, s: ast.stmt) -> None:
        if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            return  # separate trace scope; scan bodies are checked on their own
        if isinstance(s, ast.Assign):
            self._expr(s.value)
            taint = expr_taints(s.value, self.tainted)
            for t in s.targets:
                self._assign(t, taint)
        elif isinstance(s, ast.AnnAssign):
            if s.value is not None:
                self._expr(s.value)
                self._assign(s.target, expr_taints(s.value, self.tainted))
        elif isinstance(s, ast.AugAssign):
            self._expr(s.value)
            if isinstance(s.target, ast.Name):
                if expr_taints(s.value, self.tainted):
                    self.tainted.add(s.target.id)
        elif isinstance(s, (ast.If, ast.While)):
            if expr_taints(s.test, self.tainted):
                kind = "if" if isinstance(s, ast.If) else "while"
                self.violations.append(self.rule.violation(
                    self.ctx, s,
                    f"Python `{kind}` on a traced value inside a jitted/"
                    f"scanned body — trace-time control flow must branch on "
                    f"static args or shapes (use jnp.where/lax.cond for "
                    f"data-dependent logic)"))
            self._expr(s.test)
            self._block(s.body)
            self._block(s.orelse)
        elif isinstance(s, ast.For):
            self._expr(s.iter)
            self._assign(s.target, expr_taints(s.iter, self.tainted))
            self._block(s.body)
            self._block(s.orelse)
        elif isinstance(s, ast.With):
            for item in s.items:
                self._expr(item.context_expr)
                if item.optional_vars is not None:
                    self._assign(item.optional_vars,
                                 expr_taints(item.context_expr, self.tainted))
            self._block(s.body)
        elif isinstance(s, ast.Try):
            self._block(s.body)
            for h in s.handlers:
                self._block(h.body)
            self._block(s.orelse)
            self._block(s.finalbody)
        elif isinstance(s, ast.Delete):
            for t in s.targets:
                if isinstance(t, ast.Name):
                    self.tainted.discard(t.id)
        elif isinstance(s, ast.Return):
            if s.value is not None:
                self._expr(s.value)
        elif isinstance(s, (ast.Expr, ast.Raise, ast.Assert)):
            for child in ast.iter_child_nodes(s):
                self._expr(child)
        # pass/break/continue/global/import: nothing to do

    def _assign(self, target: ast.AST, taint: bool) -> None:
        if isinstance(target, ast.Name):
            if taint:
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._assign(elt, taint)
        elif isinstance(target, ast.Starred):
            self._assign(target.value, taint)
        # Subscript/Attribute targets mutate objects; no name taint change

    # -- expressions -------------------------------------------------------
    def _expr(self, e: ast.AST) -> None:
        aliases = self.ctx.analysis.aliases
        for node in walk_expr(e):
            if isinstance(node, ast.NamedExpr):
                self._assign(node.target,
                             expr_taints(node.value, self.tainted))
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            args = [*node.args, *[kw.value for kw in node.keywords]]
            if (isinstance(func, ast.Attribute) and func.attr == "item"
                    and expr_taints(func.value, self.tainted)):
                self.violations.append(self.rule.violation(
                    self.ctx, node,
                    "`.item()` on a traced value forces a host sync at "
                    "trace time (ConcretizationTypeError under jit)"))
            elif (isinstance(func, ast.Name)
                  and func.id in ("int", "float", "bool")
                  and func.id not in aliases
                  and any(expr_taints(a, self.tainted) for a in args)):
                self.violations.append(self.rule.violation(
                    self.ctx, node,
                    f"`{func.id}()` coerces a traced value to a Python "
                    f"scalar inside a jitted/scanned body"))
            else:
                name = dotted(func, aliases)
                if (name is not None
                        and name.startswith("numpy.")
                        and any(expr_taints(a, self.tainted) for a in args)):
                    self.violations.append(self.rule.violation(
                        self.ctx, node,
                        f"`{name}` called on a traced value — host numpy "
                        f"inside a jitted/scanned body concretizes the "
                        f"tracer; use jnp"))


def _jit_contexts_with_factories(ctx: FileContext):
    """This module's jit contexts, plus inner defs of local factories
    that the PROJECT jits somewhere (e.g. step.py's cont_step, jitted
    from engine.py)."""
    analysis = ctx.analysis
    contexts = dict(analysis.jit_contexts)
    for site in ctx.index.all_factory_jit_sites:
        info = ctx.index.find_factory(site.callee)
        if info is None or info.path != ctx.path:
            continue
        local = analysis.factories.get(info.name)
        if local is None:
            continue
        for inner in local.inners:
            statics = static_names_from_jit(
                site.jit_call.keywords, positional_params(inner.args))
            prev = contexts.get(inner)
            if prev is not None:
                statics = statics | prev.static_names
            contexts[inner] = JitContext(inner, statics, "factory-inner")
    return contexts


@register
class TracerLeak(Rule):
    id = "QSQ002"
    name = "tracer-leak"
    summary = (".item()/int()/float()/bool()/np.* on traced values and "
               "Python if/while on them inside jitted or scanned bodies")

    def check(self, ctx: FileContext) -> Iterator[Violation]:
        contexts = _jit_contexts_with_factories(ctx)
        for fn, jc in contexts.items():
            checker = _TracedBodyChecker(self, ctx, fn, jc.static_names)
            yield from checker.run()


# --------------------------------------------------------------------------
# QSQ003
# --------------------------------------------------------------------------
@register
class StaticArgDiscipline(Rule):
    id = "QSQ003"
    name = "static-arg-discipline"
    summary = ("demand/drop-style params must be static at every jit site; "
               "plane_mask/tiers/active must never be")

    def check(self, ctx: FileContext) -> Iterator[Violation]:
        must = set(ctx.config.static_params)
        never = set(ctx.config.never_static)
        analysis = ctx.analysis

        # (a) decorator/call-site-jitted defs in this module
        for fn, jc in analysis.jit_contexts.items():
            if jc.reason == "scan-body":
                continue
            params = set(_all_args(fn.args))
            missing = sorted((params & must) - jc.static_names)
            if missing:
                yield self.violation(
                    ctx, fn,
                    f"`{fn.name}` threads {missing} but its jit does not "
                    f"declare them static (static_argnames/static_argnums) "
                    f"— a traced demand retraces per value or leaks")
            frozen = sorted(jc.static_names & never)
            if frozen:
                yield self.violation(
                    ctx, fn,
                    f"`{fn.name}` marks {frozen} static, but these are "
                    f"traced-by-design mask-flip operands — static here "
                    f"means one retrace per tier/mask change")

        # (b) jit-the-factory-product sites, resolved cross-module
        for site in analysis.factory_jit_sites:
            info = ctx.index.find_factory(site.callee)
            if info is None:
                continue
            for inner in info.inners:
                params = positional_params(inner.args)
                statics = static_names_from_jit(site.jit_call.keywords, params)
                missing = sorted((set(params) & must) - statics)
                if missing:
                    yield Violation(
                        path=ctx.path, line=site.lineno, col=site.col,
                        rule=self.id, qualname=site.qualname,
                        message=(
                            f"jit of `{info.name}(...)` product: inner "
                            f"`{inner.name}` threads {missing} without a "
                            f"matching static_argnums/static_argnames "
                            f"(expected indices "
                            f"{[params.index(m) for m in missing]})"))
                frozen = sorted(statics & never)
                if frozen:
                    yield Violation(
                        path=ctx.path, line=site.lineno, col=site.col,
                        rule=self.id, qualname=site.qualname,
                        message=(
                            f"jit of `{info.name}(...)` product marks "
                            f"{frozen} static — these are mask-flip "
                            f"operands and must stay traced"))


# --------------------------------------------------------------------------
# QSQ004
# --------------------------------------------------------------------------
def _bound_names(fn: ast.AST) -> set[str]:
    """Every name bound anywhere inside ``fn``'s subtree (params, locals,
    nested defs and their params, loop/with/comprehension targets)."""
    bound: set[str] = set(_all_args(fn.args))
    for node in ast.walk(fn):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            bound.add(node.name)
            bound.update(_all_args(node.args))
        elif isinstance(node, ast.Lambda):
            bound.update(_all_args(node.args))
        elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            bound.add(node.id)
        elif isinstance(node, ast.ExceptHandler) and node.name:
            bound.add(node.name)
        elif isinstance(node, (ast.Import, ast.ImportFrom)):
            for a in node.names:
                bound.add(a.asname or a.name.split(".")[0])
    return bound


def _array_valued(value: ast.AST, analysis: ModuleAnalysis) -> bool:
    """Is a module-level binding's RHS an array constructor expression?"""
    for node in ast.walk(value):
        if isinstance(node, ast.Call):
            name = dotted(node.func, analysis.aliases)
            if name is not None and name.startswith(ARRAY_MODULES):
                return True
    return False


@register
class KernelPurity(Rule):
    id = "QSQ004"
    name = "kernel-purity"
    summary = ("Pallas kernel bodies must not capture arrays from enclosing "
               "scopes; BlockSpec/scratch shapes must be static expressions")

    def check(self, ctx: FileContext) -> Iterator[Violation]:
        analysis = ctx.analysis
        yield from self._check_kernel_bodies(ctx, analysis)
        yield from self._check_shapes(ctx, analysis)

    # (a) closure / module-array capture inside kernel bodies
    def _check_kernel_bodies(self, ctx, analysis) -> Iterator[Violation]:
        for kernel in analysis.kernels:
            bound = _bound_names(kernel)
            parent = analysis.fn_parent.get(kernel, analysis.module_scope)
            reported: set[str] = set()
            for node in ast.walk(kernel):
                if (not isinstance(node, ast.Name)
                        or not isinstance(node.ctx, ast.Load)
                        or node.id in bound or node.id in reported):
                    continue
                hit = parent.resolve(node.id)
                if hit is None:
                    if not is_builtin(node.id):
                        reported.add(node.id)
                    continue
                scope, binding = hit
                if scope.node is not analysis.tree:
                    reported.add(node.id)
                    yield self.violation(
                        ctx, node,
                        f"kernel `{kernel.name}` closes over `{node.id}` "
                        f"from enclosing scope `{scope.qualname}` — pass "
                        f"operands through refs/BlockSpecs and config "
                        f"through functools.partial keywords")
                elif (isinstance(binding, ast.expr)
                      and _array_valued(binding, analysis)):
                    reported.add(node.id)
                    yield self.violation(
                        ctx, node,
                        f"kernel `{kernel.name}` captures module-level "
                        f"array `{node.id}` — a closure-captured device "
                        f"array is an invisible kernel operand (no "
                        f"BlockSpec, no VMEM budget); thread it as an "
                        f"input ref")

    # (b) dynamic shapes in BlockSpec / scratch allocations
    def _check_shapes(self, ctx, analysis) -> Iterator[Violation]:
        # taint per enclosing jitted fn, so `VMEM((m, bn), ...)` with m
        # from `x.shape` passes while a traced extent fails
        taint_by_fn: dict[ast.AST, set[str]] = {}
        for fn, jc in analysis.jit_contexts.items():
            taint_by_fn[fn] = {
                a for a in _all_args(fn.args) if a not in jc.static_names
            }
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted(node.func, analysis.aliases)
            if name not in BLOCKSPEC_CALLS:
                continue
            shape_arg = None
            if node.args:
                shape_arg = node.args[0]
            else:
                for kw in node.keywords:
                    if kw.arg == "block_shape":
                        shape_arg = kw.value
            if shape_arg is None:
                continue
            # resolve the enclosing jitted fn's taint set (empty if the
            # wrapper is not jitted — the Call check still applies)
            cur = analysis.parent_map.get(node)
            tainted: set[str] = set()
            while cur is not None:
                if cur in taint_by_fn:
                    tainted = taint_by_fn[cur]
                    break
                cur = analysis.parent_map.get(cur)
            elements = (shape_arg.elts
                        if isinstance(shape_arg, (ast.Tuple, ast.List))
                        else [shape_arg])
            short = name.rsplit(".", 1)[-1]
            for elt in elements:
                calls = [n for n in walk_expr(elt) if isinstance(n, ast.Call)]
                if calls:
                    yield self.violation(
                        ctx, elt,
                        f"`{short}` shape element is computed by a call at "
                        f"trace time — block/scratch shapes must be static "
                        f"Python ints (hoist the computation before the "
                        f"pallas_call and branch on static config)")
                elif expr_taints(elt, tainted):
                    yield self.violation(
                        ctx, elt,
                        f"`{short}` shape element depends on a traced "
                        f"value — Pallas block/scratch extents are fixed "
                        f"at trace time; derive them from `.shape`/static "
                        f"args instead")


# --------------------------------------------------------------------------
# QSQ005
# --------------------------------------------------------------------------
@register
class TraceTimeCounters(Rule):
    id = "QSQ005"
    name = "trace-time-counters"
    summary = ("dispatch.counters/dispatch.traffic mutate only in the "
               "dispatch module's designated trace-time helpers")

    MUTATORS = frozenset({"clear", "update", "subtract", "pop", "popitem",
                          "setdefault", "__setitem__", "__delitem__"})

    def _is_counter(self, node: ast.AST, analysis: ModuleAnalysis,
                    objects: set[str]) -> bool:
        name = analysis.canonical(node)
        return name is not None and name in objects

    def check(self, ctx: FileContext) -> Iterator[Violation]:
        analysis = ctx.analysis
        objects = set(ctx.config.counter_objects)
        kernels = set(analysis.kernels)

        def flag(node, what: str):
            qual = analysis.qualname_of(node)
            in_kernel = any(self._inside(analysis, node, k) for k in kernels)
            if in_kernel:
                return self.violation(
                    ctx, node,
                    f"{what} inside a Pallas kernel body — counters are "
                    f"trace-time bookkeeping and must never enter a kernel")
            if ctx.config.counter_scope_allowed(ctx.path, qual):
                return None
            return self.violation(
                ctx, node,
                f"{what} outside the designated dispatch helpers "
                f"(allowed scopes: config `counter_scopes`); tests that "
                f"deliberately seed counters need a pragma + justification")

        for node in ast.walk(ctx.tree):
            v = None
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    base = t.value if isinstance(t, ast.Subscript) else t
                    if self._is_counter(base, analysis, objects):
                        v = flag(node, "dispatch counter mutation")
                        break
            elif isinstance(node, ast.Delete):
                for t in node.targets:
                    base = t.value if isinstance(t, ast.Subscript) else t
                    if self._is_counter(base, analysis, objects):
                        v = flag(node, "dispatch counter deletion")
                        break
            elif isinstance(node, ast.Call):
                func = node.func
                if (isinstance(func, ast.Attribute)
                        and func.attr in self.MUTATORS
                        and self._is_counter(func.value, analysis, objects)):
                    v = flag(node, f"dispatch counter `.{func.attr}()`")
            if v is not None:
                yield v

    @staticmethod
    def _inside(analysis: ModuleAnalysis, node: ast.AST,
                kernel: ast.AST) -> bool:
        cur = node
        while cur is not None:
            if cur is kernel:
                return True
            cur = analysis.parent_map.get(cur)
        return False

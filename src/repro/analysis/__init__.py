"""qsqlint — repo-specific static analysis for jit/trace hygiene and
packed-weight invariants.

The serving stack's performance story rests on contracts that no general
linter knows about: packed weights must never materialize dense on a hot
path, the continuous-batching programs must trace once per (family,
demand-tier) and never retrace on admit/evict, plane demand must stay a
static jit argument, Pallas kernel bodies must stay pure, and the
dispatch counters must only mutate at trace time.  This package checks
those contracts on the AST, before a kernel ever runs:

* :mod:`repro.analysis.rules`   — the QSQ001..QSQ005 rule registry;
* :mod:`repro.analysis.linter`  — file/project orchestration + pragmas;
* :mod:`repro.analysis.config`  — per-rule config and allowlists;
* :mod:`repro.analysis.retrace` — the runtime companion
  (:func:`~repro.analysis.retrace.no_retrace`), asserting at run time
  what QSQ002/QSQ003 argue statically.

CLI: ``python -m repro.analysis src tests benchmarks`` (nonzero exit on
violations).  Inline suppression: ``# qsqlint: disable=QSQ001 -- why``.
"""
from repro.analysis.config import Config, load_config
from repro.analysis.linter import Violation, lint_file, lint_paths
from repro.analysis.rules import RULES

__all__ = [
    "Config",
    "RULES",
    "Violation",
    "lint_file",
    "lint_paths",
    "load_config",
]

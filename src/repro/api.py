"""The quality-dial facade: compress → EdgeArtifact → engine, one import.

    from repro import api

    art = api.compress(model, params)          # policy -> 3-bit wire
    art.save("model.edge.npz")
    art = api.load("model.edge.npz")           # self-describing npz
    eng = art.engine(quality="mid", batch_slots=4)
    eng.generate([[1, 2, 3]], max_new=16)
    eng.set_quality("lo")                      # re-dial, no reload/requant

Everything here is a re-export of :mod:`repro.quant.artifact`; the legacy
entry points (``quantize_pytree`` → ``pack_pytree_wire`` → ``export_wire``
→ ``load_wire`` → ``tree_from_wire`` → ``ServeEngine.from_wire``) remain
as thin delegates for existing callers.
"""
from repro.quant.artifact import (
    DEFAULT_TIERS,
    ArtifactIntegrityError,
    EdgeArtifact,
    QualitySpec,
    QualityTier,
    compress,
    default_policy,
)
from repro.serve import (
    AdmissionPolicy,
    FinishReason,
    QualityShed,
    RequestStatus,
    SLOBudget,
    SpecConfig,
    SubmitRejected,
)

load = EdgeArtifact.load

__all__ = [
    "DEFAULT_TIERS",
    "AdmissionPolicy",
    "ArtifactIntegrityError",
    "EdgeArtifact",
    "FinishReason",
    "QualitySpec",
    "QualityShed",
    "QualityTier",
    "RequestStatus",
    "SLOBudget",
    "SpecConfig",
    "SubmitRejected",
    "compress",
    "default_policy",
    "load",
]

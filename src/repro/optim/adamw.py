"""AdamW in pure JAX, descriptor-aware so the optimizer state inherits the
params' sharding (m/v are f32 regardless of param dtype)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.base import ParamDesc, _is_desc


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array  # () int32


def adamw_init_descs(param_descs) -> OptState:
    """Descriptor tree for the optimizer state (f32 moments, zeros)."""

    def f32_zeros(d: ParamDesc) -> ParamDesc:
        return ParamDesc(d.shape, d.axes, dtype=jnp.float32, init="zeros")

    m = jax.tree_util.tree_map(f32_zeros, param_descs, is_leaf=_is_desc)
    v = jax.tree_util.tree_map(f32_zeros, param_descs, is_leaf=_is_desc)
    return OptState(m=m, v=v, step=ParamDesc((), (), dtype=jnp.int32, init="zeros"))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(a.astype(jnp.float32) ** 2) for a in leaves)
    )


def adamw_update(
    cfg: AdamWConfig,
    params,
    grads,
    opt: OptState,
    lr_scale: jax.Array | float = 1.0,
):
    """One AdamW step.  Returns (new_params, new_opt, grad_norm)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = opt.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * clip
        m2 = cfg.b1 * m + (1 - cfg.b1) * g32
        v2 = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p2, m2, v2

    out = jax.tree_util.tree_map(upd, params, grads, opt.m, opt.v)
    outer = jax.tree_util.tree_structure(params)
    inner = jax.tree_util.tree_structure((0, 0, 0))
    new_params, new_m, new_v = jax.tree_util.tree_transpose(outer, inner, out)
    return new_params, OptState(m=new_m, v=new_v, step=step), gnorm

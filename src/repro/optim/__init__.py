from repro.optim.adamw import AdamWConfig, OptState, adamw_init_descs, adamw_update
from repro.optim.compression import GradCompressionConfig, compress_grads, compression_state_descs
from repro.optim.schedule import cosine_schedule

__all__ = [
    "AdamWConfig", "OptState", "adamw_init_descs", "adamw_update",
    "cosine_schedule", "GradCompressionConfig", "compression_state_descs",
    "compress_grads",
]

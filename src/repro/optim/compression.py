"""QSQ gradient compression with error feedback (DESIGN.md §7.1).

The paper encodes the *model* in 3-bit form before it crosses the
communication channel.  At training scale the analogous channel is the
cross-pod gradient all-reduce (DCN is ~25x slower than ICI), so we apply the
same codec to gradients: each 2-D+ grad leaf is QSQ-encoded
(3 bits + one f32 scalar per group) and decoded on the other side; the
quantization residual is kept in an error-feedback accumulator and added to
the next step's gradient, which keeps SGD/Adam convergence (Karimireddy et
al. 2019 — error feedback fixes sign-style compression).

Under pjit the all-reduce is implicit, so "compress -> transmit ->
decompress" is expressed as quantize -> dequantize around the optimizer.
The wire-format byte count (what would actually cross DCN) is returned as a
metric; on a real multi-pod deployment the encode runs through the
``qsq_quantize`` Pallas kernel before the hierarchical reduce.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.qsq import QSQConfig, dequantize, quantize
from repro.models.base import ParamDesc, _is_desc


@dataclasses.dataclass(frozen=True)
class GradCompressionConfig:
    enabled: bool = False
    phi: int = 4
    group_size: int = 64
    min_numel: int = 4096  # small leaves cross uncompressed


def _compressible(shape) -> bool:
    return len(shape) >= 2


def compression_state_descs(param_descs, cc: GradCompressionConfig):
    """Error-feedback residual buffers (f32) for compressible leaves; a ()
    placeholder for the rest (keeps the pytree structure aligned)."""

    def leaf(d: ParamDesc) -> ParamDesc:
        if cc.enabled and _compressible(d.shape) and int(np.prod(d.shape)) >= cc.min_numel:
            return ParamDesc(d.shape, d.axes, dtype=jnp.float32, init="zeros")
        return ParamDesc((), (), dtype=jnp.float32, init="zeros")

    return jax.tree_util.tree_map(leaf, param_descs, is_leaf=_is_desc)


def _leaf_group(shape, group_size: int) -> int:
    g = group_size
    while shape[0] % g != 0 and g > 1:
        g //= 2
    return max(g, 1)


def compress_grads(grads, err_state, cc: GradCompressionConfig):
    """(grads, err) -> (decoded grads as transmitted, new err, wire_bytes)."""
    if not cc.enabled:
        return grads, err_state, jnp.float32(0.0)

    wire_bits = [jnp.float32(0.0)]

    def leaf(g, e):
        if e.ndim == 0:  # not compressed
            return g, e
        g32 = g.astype(jnp.float32) + e
        # flatten trailing dims so grouping runs along the leading axis
        flat = g32.reshape(g32.shape[0], -1)
        gs = _leaf_group(flat.shape, cc.group_size)
        q = quantize(flat, QSQConfig(phi=cc.phi, group_size=gs, assign="nearest"))
        dec = dequantize(q).reshape(g32.shape)
        wire_bits[0] = wire_bits[0] + (
            3.0 * flat.size + 32.0 * q.scales.size
        )
        return dec.astype(g.dtype), g32 - dec

    out = jax.tree_util.tree_map(leaf, grads, err_state)
    outer = jax.tree_util.tree_structure(grads)
    inner = jax.tree_util.tree_structure((0, 0))
    dec_grads, new_err = jax.tree_util.tree_transpose(outer, inner, out)
    return dec_grads, new_err, wire_bits[0] / 8.0  # bytes

"""Bit-level packing of QSQ codes.

Two physical layouts:

* **Dense pack** (`pack_dense` / `unpack_dense`): 10 3-bit codes per int32
  word (or 16 2-bit codes for ternary).  This is the *wire/checkpoint* format
  — what the paper sends over the communication channel to the edge device.

* **Bit-plane pack** (`pack_bitplane` / `unpack_bitplane`): the 3 bits of 32
  consecutive codes are split into 3 int32 words (one per bit position).
  This is the *kernel* format: power-of-two aligned along the contraction
  dim, so a Pallas tile can unpack codes with three shifts + masks per 32
  weights, mirroring the paper's shift-and-invert decoder (Table II) in
  VREG arithmetic.

All functions are jit-compatible with static shapes.
"""
from __future__ import annotations

import zlib

import jax
import jax.numpy as jnp
import numpy as np

DENSE_CODES_PER_WORD = {3: 10, 2: 16}
PLANE_GROUP = 32  # codes per bit-plane word


# --------------------------------------------------------------------------
# Dense (wire) format
# --------------------------------------------------------------------------
def dense_words(n_codes: int, bits: int = 3) -> int:
    per = DENSE_CODES_PER_WORD[bits]
    return (n_codes + per - 1) // per


def pack_dense(codes: jax.Array, bits: int = 3) -> jax.Array:
    """Pack a flat uint8 code array into int32 words (wire format)."""
    per = DENSE_CODES_PER_WORD[bits]
    n = codes.shape[0]
    nw = dense_words(n, bits)
    padded = jnp.zeros(nw * per, dtype=jnp.uint32).at[:n].set(
        codes.astype(jnp.uint32)
    )
    lanes = padded.reshape(nw, per)
    shifts = jnp.arange(per, dtype=jnp.uint32) * bits
    word = jnp.sum(lanes << shifts[None, :], axis=1, dtype=jnp.uint32)
    return word.astype(jnp.int32)


def unpack_dense(words: jax.Array, n_codes: int, bits: int = 3) -> jax.Array:
    """Inverse of :func:`pack_dense`."""
    per = DENSE_CODES_PER_WORD[bits]
    shifts = jnp.arange(per, dtype=jnp.uint32) * bits
    mask = jnp.uint32((1 << bits) - 1)
    lanes = (words.astype(jnp.uint32)[:, None] >> shifts[None, :]) & mask
    return lanes.reshape(-1)[:n_codes].astype(jnp.uint8)


# --------------------------------------------------------------------------
# Bit-plane (kernel) format
# --------------------------------------------------------------------------
def pack_bitplane(codes: jax.Array, bits: int = 3) -> jax.Array:
    """Pack codes (K, ...) -> (K // 32, bits, ...) int32 bit-planes.

    K must be a multiple of 32.  Bit p of word [g, p, ...] holds bit p of
    code ``codes[g*32 + j, ...]`` at bit position j.
    """
    k = codes.shape[0]
    if k % PLANE_GROUP != 0:
        raise ValueError(f"K={k} must be a multiple of {PLANE_GROUP}")
    c = codes.astype(jnp.uint32).reshape(k // PLANE_GROUP, PLANE_GROUP, *codes.shape[1:])
    j = jnp.arange(PLANE_GROUP, dtype=jnp.uint32).reshape(
        (1, PLANE_GROUP) + (1,) * (codes.ndim - 1)
    )
    planes = []
    for p in range(bits):
        bit = (c >> np.uint32(p)) & jnp.uint32(1)
        planes.append(jnp.sum(bit << j, axis=1, dtype=jnp.uint32))
    out = jnp.stack(planes, axis=1)  # (K//32, bits, ...)
    return out.astype(jnp.int32)


def unpack_bitplane(planes: jax.Array, bits: int = 3) -> jax.Array:
    """Inverse of :func:`pack_bitplane`: (K//32, bits, ...) -> (K, ...) uint8."""
    p32 = planes.astype(jnp.uint32)
    j = jnp.arange(PLANE_GROUP, dtype=jnp.uint32).reshape(
        (1, PLANE_GROUP) + (1,) * (planes.ndim - 2)
    )
    code = jnp.zeros(
        (planes.shape[0], PLANE_GROUP) + planes.shape[2:], dtype=jnp.uint32
    )
    for p in range(bits):
        bit = (p32[:, p][:, None] >> j) & jnp.uint32(1)
        code = code | (bit << np.uint32(p))
    return code.reshape((planes.shape[0] * PLANE_GROUP,) + planes.shape[2:]).astype(
        jnp.uint8
    )


# --------------------------------------------------------------------------
# Plane-major (streaming) layout
# --------------------------------------------------------------------------
# pack_bitplane interleaves planes along the contraction dim:
#   (K//32, bits, ...), LSB first.  Demand-driven streaming wants the plane
# index OUTERMOST and MSB first, so the planes a truncated tier keeps are a
# contiguous leading prefix and a dropped plane shortens the HBM read
# instead of being masked after the load.
def plane_major(planes: jax.Array, bits: int = 3) -> jax.Array:
    """(K//32, bits, ...) interleaved -> (bits, K//32, ...) MSB-first."""
    return jnp.flip(jnp.moveaxis(planes, 1, 0), axis=0)


def plane_interleaved(pm: jax.Array, bits: int = 3) -> jax.Array:
    """Inverse of :func:`plane_major`."""
    return jnp.moveaxis(jnp.flip(pm, axis=0), 0, 1)


def unpack_bitplane_major(
    pm: jax.Array, bits: int = 3, n_planes: int | None = None
) -> jax.Array:
    """(P, K//32, ...) MSB-first plane-major words -> (K, ...) uint8 codes.

    Only the leading ``n_planes`` planes are read (default: all present);
    missing trailing planes contribute zero bits, matching a truncated
    stream.
    """
    np_ = pm.shape[0] if n_planes is None else n_planes
    p32 = pm.astype(jnp.uint32)
    j = jnp.arange(PLANE_GROUP, dtype=jnp.uint32).reshape(
        (1, PLANE_GROUP) + (1,) * (pm.ndim - 2)
    )
    code = jnp.zeros(
        (pm.shape[1], PLANE_GROUP) + pm.shape[2:], dtype=jnp.uint32
    )
    for p in range(np_):
        bit = (p32[p][:, None] >> j) & jnp.uint32(1)
        code = code | (bit << np.uint32(bits - 1 - p))
    return code.reshape((pm.shape[1] * PLANE_GROUP,) + pm.shape[2:]).astype(
        jnp.uint8
    )


# --------------------------------------------------------------------------
# Per-plane integrity (degraded-wire serving)
# --------------------------------------------------------------------------
def plane_crcs(codes, bits: int = 3) -> tuple[int, ...]:
    """Per-bit-plane CRC32s of a code tensor, MSB FIRST (host-side).

    Entry 0 covers the sign/MSB plane, the last entry the trailing LSB
    plane — the same order the plane-major streaming layout stores and a
    partial download truncates.  A receiver that checks these against an
    artifact's stored values can tell WHICH planes a channel damaged:
    trailing-LSB damage is recoverable (zero the plane — bit-identical
    to a truncated download, i.e. a lower quality tier), MSB damage is
    not.  CRCs are computed over the packed bit rows, so they are layout
    independent (dense wire words and plane-major kernel words agree).
    """
    c = np.asarray(codes, dtype=np.uint8).reshape(-1)
    out = []
    for p in range(bits - 1, -1, -1):  # MSB first
        row = np.packbits((c >> p) & np.uint8(1))
        out.append(zlib.crc32(row.tobytes()) & 0xFFFFFFFF)
    return tuple(out)


# --------------------------------------------------------------------------
# Wire-format byte accounting (drives the Eq. 11/12 energy model)
# --------------------------------------------------------------------------
def wire_bytes(n_codes: int, n_scales: int, bits: int = 3, scalar_bits: int = 32) -> int:
    """Bytes on the channel for a packed tensor: codes + full-precision scalars."""
    return 4 * dense_words(n_codes, bits) + (scalar_bits // 8) * n_scales

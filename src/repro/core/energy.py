"""Eq. 11/12 memory + DRAM-energy model, extended with TPU roofline constants.

The paper's energy model is a bandwidth model: energy = bits moved from DRAM
x energy-per-bit (6400 pJ per 32-bit DRAM access, after [Yang et al. CVPR'17]).
On TPU the same quantity (bytes moved from HBM) is the numerator of the
roofline *memory term*, so this module serves both the paper-faithful
benchmarks (Fig. 9/10) and the §Roofline analysis.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

# Paper constants
DRAM_PJ_PER_32B_ACCESS = 6400.0  # pJ to move 32 bits from DRAM (Fig. 1, [8])
FPB = 32  # Full Precision Bits

# TPU v5e constants (per chip) — per the assignment spec
TPU_PEAK_BF16_FLOPS = 197e12  # 197 TFLOP/s
TPU_HBM_BW = 819e9  # 819 GB/s
TPU_ICI_BW = 50e9  # ~50 GB/s per link


def nbits_unquantized(numel: int, fpb: int = FPB) -> int:
    """Eq. 11: bits to store a full-precision tensor."""
    return fpb * numel


def nbits_quantized(
    numel: int, group_size: int, bit_encoding: int = 3, fpb: int = FPB
) -> int:
    """Eq. 12 generalized: BE bits per element + one fpb scalar per group."""
    n_scalars = numel // group_size
    return bit_encoding * numel + fpb * n_scalars


def nbits_conv_layer(
    h: int, w: int, c: int, num: int, group_size: int | None = None,
    bit_encoding: int = 3, fpb: int = FPB,
) -> int:
    """Eq. 11/12 verbatim for a conv layer (H, W, C, Num filters).

    The paper's Eq. 12 forms vectors across the ``Num`` filters at each
    (h, w, c) position, i.e. group_size == Num, giving H*W*C scalars.  Pass
    group_size=None for that faithful reading.
    """
    numel = h * w * c * num
    if group_size is None:
        return bit_encoding * numel + h * w * c * fpb
    return nbits_quantized(numel, group_size, bit_encoding, fpb)


def memory_savings(numel: int, group_size: int, bit_encoding: int = 3) -> float:
    """Fractional model-size reduction, 1 - quantized/full (paper: 82.49%)."""
    return 1.0 - nbits_quantized(numel, group_size, bit_encoding) / nbits_unquantized(numel)


def dram_energy_pj(nbits: int) -> float:
    """DRAM transfer energy for nbits (paper's 6400 pJ / 32-bit model)."""
    return (nbits / 32.0) * DRAM_PJ_PER_32B_ACCESS


def energy_savings(numel: int, group_size: int, bit_encoding: int = 3) -> float:
    """Fractional DRAM-energy saving (paper: 88.82% @3b, 91.95% @2b ConvNet)."""
    full = dram_energy_pj(nbits_unquantized(numel))
    q = dram_energy_pj(nbits_quantized(numel, group_size, bit_encoding))
    return 1.0 - q / full


@dataclasses.dataclass(frozen=True)
class LayerShape:
    """One conv/dense layer for the Eq. 11/12 sweeps."""

    name: str
    h: int
    w: int
    c: int
    num: int

    @property
    def numel(self) -> int:
        return self.h * self.w * self.c * self.num


def model_savings(
    layers: Sequence[LayerShape], group_size: int, bit_encoding: int = 3
) -> dict:
    """Aggregate Eq. 11/12 over a model's layers (Fig. 9 reproduction)."""
    full_bits = sum(nbits_unquantized(ls.numel) for ls in layers)
    q_bits = sum(nbits_quantized(ls.numel, group_size, bit_encoding) for ls in layers)
    return {
        "full_bits": full_bits,
        "quantized_bits": q_bits,
        "memory_savings": 1.0 - q_bits / full_bits,
        "energy_savings": 1.0 - dram_energy_pj(q_bits) / dram_energy_pj(full_bits),
        "full_dram_pj": dram_energy_pj(full_bits),
        "quantized_dram_pj": dram_energy_pj(q_bits),
    }


# --------------------------------------------------------------------------
# TPU roofline terms (aggregated by benchmarks/roofline.py)
# --------------------------------------------------------------------------
def roofline_terms(
    hlo_flops: float,
    hlo_bytes: float,
    collective_bytes: float,
    n_chips: int,
    peak_flops: float = TPU_PEAK_BF16_FLOPS,
    hbm_bw: float = TPU_HBM_BW,
    ici_bw: float = TPU_ICI_BW,
) -> dict:
    """The three roofline terms in seconds + the dominant bottleneck."""
    compute_s = hlo_flops / (n_chips * peak_flops)
    memory_s = hlo_bytes / (n_chips * hbm_bw)
    collective_s = collective_bytes / (n_chips * ici_bw)
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "bound_s": bound,
        "roofline_fraction": compute_s / bound if bound > 0 else 0.0,
    }

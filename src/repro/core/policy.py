"""Per-layer quantization policy — which params get QSQ, at which quality.

The paper quantizes conv-filter weights layer by layer and notes (Fig. 8)
that layers differ in sensitivity.  At framework scale that becomes a policy
object: a pytree-path -> QSQConfig mapping with sensible defaults
(2-D+ weight matrices are quantized; norms/scales/biases and other small
1-D params stay full precision) plus a sensitivity-driven search that
assigns the quality knob phi per layer under a bit budget (DESIGN.md §7.4).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Mapping

import jax
import numpy as np

from repro.core.qsq import QSQConfig

# Param-path regexes that should never be quantized (tiny and sensitive).
# Matched case-SENSITIVELY against the '/'-joined pytree path.
DEFAULT_EXCLUDE = (
    "norm", "scale", "bias", "ln_", "_ln", "ln[0-9]",
    "a_log", "dt_bias", r"(^|/)D($|/)",  # Mamba decay / skip params
)


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Decides, per parameter, whether/how to quantize.

    Attributes:
      base: the QSQConfig applied to quantized params.
      min_numel: params smaller than this stay full precision.
      min_ndim: params with fewer dims stay full precision (biases, norms).
      exclude_res: regexes over the '/'-joined pytree path; matches are kept
        full precision.
      overrides: path-regex -> QSQConfig for layer-specific quality (the
        paper's per-layer exhaustive search output plugs in here).
      quantize_embeddings: embedding tables are huge (phi4: 200k vocab) and
        benefit most from compression but can be sensitive; default on.
    """

    base: QSQConfig = QSQConfig()
    min_numel: int = 1024
    min_ndim: int = 2
    exclude_res: tuple = DEFAULT_EXCLUDE
    overrides: Mapping[str, QSQConfig] = dataclasses.field(default_factory=dict)
    quantize_embeddings: bool = True

    def config_for(self, path: str, shape: tuple) -> QSQConfig | None:
        """QSQConfig for this param, or None to keep it full precision."""
        numel = int(np.prod(shape)) if shape else 1
        if len(shape) < self.min_ndim or numel < self.min_numel:
            return None
        for pat in self.exclude_res:
            if re.search(pat, path):
                return None
        if not self.quantize_embeddings and "embed" in path.lower():
            return None
        for pat, cfg in self.overrides.items():
            if re.search(pat, path):
                return cfg
        # Group size must divide the leading dim; shrink if needed.
        g = self.base.group_size
        while shape[0] % g != 0:
            g //= 2
            if g == 0:
                return None
        if g != self.base.group_size:
            return dataclasses.replace(self.base, group_size=g)
        return self.base


def path_str(path) -> str:
    """jax.tree_util key path -> 'a/b/0/c' string."""
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        else:
            parts.append(str(k))
    return "/".join(parts)


def sensitivity_rank(
    params,
    loss_fn: Callable,
    policy: QuantPolicy,
    batch,
) -> list[tuple[str, float]]:
    """Rank quantizable layers by quantization-induced loss increase.

    Systematizes the paper's exhaustive per-layer search (Fig. 8): quantize
    ONE layer at a time with ``policy.base``, measure the loss delta on a
    calibration batch, sort descending (most sensitive first).
    """
    from repro.core import qsq as _qsq

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    base_loss = float(loss_fn(params, batch))
    results = []
    for i, (path, leaf) in enumerate(flat):
        p = path_str(path)
        cfg = policy.config_for(p, leaf.shape)
        if cfg is None:
            continue
        q = _qsq.quantize(leaf, cfg)
        leaves = [leaf2 for (_, leaf2) in flat]
        leaves[i] = q.dequantize(leaf.dtype)
        mutated = jax.tree_util.tree_unflatten(treedef, leaves)
        results.append((p, float(loss_fn(mutated, batch)) - base_loss))
    return sorted(results, key=lambda t: -t[1])


def budgeted_policy(
    sens: list[tuple[str, float]],
    policy: QuantPolicy,
    phi_by_rank=(4, 4, 2, 1),
) -> QuantPolicy:
    """Assign higher phi (more levels) to more sensitive layers.

    ``phi_by_rank`` gives phi for sensitivity quartiles, most->least
    sensitive.  Returns a policy with per-layer overrides.
    """
    if not sens:
        return policy
    n = len(sens)
    overrides = dict(policy.overrides)
    for rank, (path, _) in enumerate(sens):
        quartile = min(len(phi_by_rank) - 1, (rank * len(phi_by_rank)) // n)
        overrides[re.escape(path)] = dataclasses.replace(
            policy.base, phi=phi_by_rank[quartile]
        )
    return dataclasses.replace(policy, overrides=overrides)

"""Canonic Signed Digit (CSD) arithmetic — the paper's Quality Scalable
Multiplier, adapted to TPU.

The paper's second component replaces exact multipliers with approximate ones
that (a) recode the multiplicand into CSD form (digits in {-1, 0, +1}, no two
adjacent non-zeros — the representation with the provably minimum number of
non-zero digits), and (b) truncate least-significant non-zero digits to cut
partial products, saving energy via gate clocking.

**TPU adaptation (see DESIGN.md §2):** the MXU is a fixed dense systolic
array — partial products cannot be skipped.  What *does* transfer is the
numerics: multiplying by a k-digit-truncated CSD weight is exactly
multiplying by ``csd_round(w, k)``.  So we implement CSD as a *weight
rounding mode*: any weight tensor can be replaced by its nearest value
representable with <= k non-zero CSD digits, and the induced error/accuracy
trade-off is the paper's quality-scalability knob.  We also reproduce the
Fig. 11 statistic (distribution of non-zero CSD digits in trained weights).

The greedy nearest-signed-power-of-two residual expansion used below is the
classic CSD recoding: at each step the remaining residual is reduced by its
nearest signed power of two, which reproduces the most-significant-first CSD
digits; stopping after k steps == truncating the k+1-th and later partial
products.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.jit, static_argnames=("max_digits", "min_exp", "max_exp"))
def csd_round(
    w: jax.Array,
    max_digits: int = 3,
    min_exp: int = -16,
    max_exp: int = 15,
) -> jax.Array:
    """Round to the nearest value with <= max_digits non-zero CSD digits.

    Exponents are clamped to [min_exp, max_exp] (a 32-bit fixed-point-like
    range by default, matching the paper's MATLAB ``fi`` analysis).
    """
    w = w.astype(jnp.float32)
    residual = w
    approx = jnp.zeros_like(w)
    for _ in range(max_digits):
        a = jnp.abs(residual)
        # nearest power of two: exponent = floor(log2(|r| * 4/3)); the 4/3
        # factor puts the rounding boundary at the geometric midpoint
        # sqrt(2^e * 2^(e+1)) ~ 1.5 * 2^e -> boundary |r| = 1.5*2^e.
        safe = jnp.where(a > 0, a, 1.0)
        e = jnp.floor(jnp.log2(safe * (4.0 / 3.0)))
        e = jnp.clip(e, min_exp, max_exp)
        term = jnp.sign(residual) * jnp.exp2(e)
        term = jnp.where(a > jnp.exp2(min_exp - 1), term, 0.0)
        approx = approx + term
        residual = residual - term
    return approx


def csd_digit_count(
    w: jax.Array, frac_bits: int = 16, total_bits: int = 30
) -> jax.Array:
    """Number of non-zero CSD digits of each weight at fixed-point precision.

    Reproduces the Fig. 11 statistic: quantize w to ``total_bits`` fixed point
    with ``frac_bits`` fractional bits, then count non-zero digits of the
    canonical signed-digit recoding (NAF) of the integer.

    total_bits <= 30 so that the NAF helper ``u + (u >> 1)`` cannot overflow
    uint32 (the default JAX config has no 64-bit ints).
    """
    scale = float(2**frac_bits)
    x = jnp.round(w.astype(jnp.float32) * scale).astype(jnp.int32)
    lim = 2 ** (total_bits - 1) - 1
    x = jnp.clip(x, -lim, lim)
    u = jnp.abs(x).astype(jnp.uint32)
    # Non-zero CSD digit count of u == popcount of the NAF support:
    #   h = u + (u >> 1);  nonzeros = popcount(h ^ (u >> 1)).
    h = u + (u >> np.uint32(1))
    naf_nonzeros = _popcount32(h ^ (u >> np.uint32(1)))
    return naf_nonzeros.astype(jnp.int32)


def _popcount32(x: jax.Array) -> jax.Array:
    x = x.astype(jnp.uint32)
    m1 = np.uint32(0x55555555)
    m2 = np.uint32(0x33333333)
    m4 = np.uint32(0x0F0F0F0F)
    h01 = np.uint32(0x01010101)
    x = x - ((x >> np.uint32(1)) & m1)
    x = (x & m2) + ((x >> np.uint32(2)) & m2)
    x = (x + (x >> np.uint32(4))) & m4
    return ((x * h01) >> np.uint32(24)).astype(jnp.int32)


def csd_nonzero_histogram(w: jax.Array, frac_bits: int = 16, max_count: int = 33):
    """Histogram of non-zero CSD digit counts (Fig. 11 reproduction)."""
    counts = csd_digit_count(w.reshape(-1), frac_bits=frac_bits)
    return jnp.bincount(counts, length=max_count)


def partial_product_savings(w: jax.Array, max_digits: int, frac_bits: int = 16):
    """Fraction of partial products an approximate CSD multiplier would skip.

    Exact multiplier cost model: one partial product per non-zero CSD digit.
    The quality-scalable multiplier caps digits at ``max_digits``.
    """
    counts = csd_digit_count(w.reshape(-1), frac_bits=frac_bits).astype(jnp.float32)
    exact = jnp.sum(counts)
    kept = jnp.sum(jnp.minimum(counts, float(max_digits)))
    return jnp.where(exact > 0, 1.0 - kept / exact, 0.0)

"""QSQ core: quantizer (Eq. 5-10), codec (Table II), CSD multipliers, energy model."""
from repro.core import codec, csd, energy
from repro.core.policy import QuantPolicy, budgeted_policy, sensitivity_rank
from repro.core.qsq import (
    LEVEL_TABLE,
    QSQConfig,
    QSQTensor,
    bits_per_code,
    codes_to_levels,
    dequantize,
    exhaustive_threshold_search,
    levels_for_phi,
    levels_to_codes,
    quantization_error,
    quantize,
    theta_levels,
    zeros_fraction,
)

__all__ = [
    "QSQConfig", "QSQTensor", "quantize", "dequantize", "quantization_error",
    "zeros_fraction", "levels_for_phi", "bits_per_code", "theta_levels", "levels_to_codes",
    "codes_to_levels", "exhaustive_threshold_search", "LEVEL_TABLE",
    "codec", "csd", "energy", "QuantPolicy", "sensitivity_rank", "budgeted_policy",
]

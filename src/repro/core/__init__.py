"""QSQ core: quantizer (Eq. 5-10), codec (Table II), CSD multipliers, energy model."""
from repro.core.qsq import (
    QSQConfig,
    QSQTensor,
    quantize,
    dequantize,
    quantization_error,
    zeros_fraction,
    levels_for_phi,
    bits_per_code,
    theta_levels,
    levels_to_codes,
    codes_to_levels,
    exhaustive_threshold_search,
    LEVEL_TABLE,
)
from repro.core import codec, csd, energy
from repro.core.policy import QuantPolicy, sensitivity_rank, budgeted_policy

__all__ = [
    "QSQConfig", "QSQTensor", "quantize", "dequantize", "quantization_error",
    "zeros_fraction", "levels_for_phi", "bits_per_code", "theta_levels", "levels_to_codes",
    "codes_to_levels", "exhaustive_threshold_search", "LEVEL_TABLE",
    "codec", "csd", "energy", "QuantPolicy", "sensitivity_rank", "budgeted_policy",
]

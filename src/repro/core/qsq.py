"""Quality Scalable Quantization (QSQ) — the paper's core contribution.

Implements Eq. 5-10 of "Quality Scalable Quantization Methodology for Deep
Learning on Edge" (Khaliq & Hafiz):

  * weights are split into vectors ("groups") of length N,
  * each group gets one full-precision scalar  alpha = sum(|w|) / (phi * N)   (Eq. 9)
  * each element gets a level from the power-of-two alphabet
        beta in {0, +-1, +-2, +-4}                                            (Eq. 6)
    capped by the quality knob phi in {1, 2, 4} (number of magnitude levels,
    Eq. 8),
  * the level assignment uses positive/negative deviations sigma_P/sigma_N
    with thresholds (delta, gamma)                                            (Eq. 10),
  * dequantization is  w_hat = alpha * beta  — on hardware: shift + invert
    of the scalar (Table II).

Everything here is pure jnp and jit-compatible.  The 3-bit packing lives in
``repro.core.codec``; the Pallas fused dequant-matmul lives in
``repro.kernels``.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

# Table II of the paper: 3-bit code -> quantization level.
#   000 -> 0 (skipped)        100 -> -1 (invert)
#   001 -> +1 (no shift)      101 -> -2 (invert + shift)
#   010 -> +2 (shift left 1)  110 -> -4 (invert + shift twice)
#   011 -> +4 (shift left 2)  111 -> unused
LEVEL_TABLE = np.array([0, 1, 2, 4, -1, -2, -4, 0], dtype=np.int8)

# level value -> 3-bit code (inverse of LEVEL_TABLE for valid codes)
_LEVEL_TO_CODE = {0: 0, 1: 1, 2: 2, 4: 3, -1: 4, -2: 5, -4: 6}

AssignMode = Literal["sigma", "nearest"]


def theta_levels(phi: int) -> int:
    """Eq. 8: number of non-negative magnitude levels for quality knob phi."""
    if phi not in (1, 2, 4):
        raise ValueError(f"phi must be one of 1, 2, 4; got {phi}")
    return int(np.ceil(np.log2(2 * (1 + np.log2(phi))))) + 1


def bits_per_code(phi: int) -> int:
    """Wire bits per weight: 3-bit Table II codes for phi in {2,4}; the
    ternary phi=1 alphabet {0,+-1} fits in 2 bits.  Single source of truth
    for QSQConfig.bits_per_code and every nbits() accounting."""
    theta_levels(phi)  # validate
    return 2 if phi == 1 else 3


def levels_for_phi(phi: int) -> np.ndarray:
    """Signed level alphabet for a given phi.

    phi=1 -> {0, +-1};  phi=2 -> {0, +-1, +-2};  phi=4 -> {0, +-1, +-2, +-4}.
    """
    mags = [0, 1, 2, 4][: theta_levels(phi)]
    pos = [m for m in mags if m > 0]
    return np.array([0] + pos + [-m for m in pos], dtype=np.int8)


@dataclasses.dataclass(frozen=True)
class QSQConfig:
    """Hyper-parameters of the quantizer.

    Attributes:
      phi: quality knob (1, 2 or 4).  Higher phi = more levels = higher quality.
      group_size: vector length N over which one scalar alpha is shared.
      assign: "sigma" is the paper's Eq. 10 threshold rule; "nearest" picks
        argmin_beta |w - alpha*beta| (the direct minimizer of Eq. 5 given
        alpha — the paper finds thresholds by exhaustive search, and the
        nearest rule is the fixed point of that search).
      delta: Eq. 10 outer threshold multiplier (levels 2 vs 4 boundary).
      gamma_frac: zero-threshold as a fraction of alpha (the paper's gamma is
        an absolute per-vector number; we parameterize it relative to alpha so
        one setting works for every layer scale).
      refit_alpha: BEYOND-PAPER improvement (off by default = paper-faithful).
        After level assignment, refit alpha per group by least squares
        (alpha* = <w, beta> / <beta, beta>) and re-assign once (one Lloyd
        iteration).  The wire format is unchanged — still 3-bit codes + one
        scalar — but reconstruction error drops several-fold because the
        paper's Eq. 9 scalar clips everything above mean|w|.
    """

    phi: int = 4
    group_size: int = 16
    assign: AssignMode = "nearest"
    delta: float = 2.0
    gamma_frac: float = 0.5
    refit_alpha: bool = False

    def __post_init__(self):
        theta_levels(self.phi)  # validate
        if self.group_size < 1:
            raise ValueError("group_size must be >= 1")

    @property
    def max_level(self) -> int:
        return int(2 ** (theta_levels(self.phi) - 2)) if self.phi > 1 else 1

    @property
    def bits_per_code(self) -> int:
        """3-bit encoding for phi in {2,4}; ternary (phi=1) fits in 2 bits."""
        return bits_per_code(self.phi)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QSQTensor:
    """A quantized tensor: signed level values + per-group scalars.

    ``levels`` holds the *signed level values* in {0,+-1,+-2,+-4} as int8 —
    the human-readable form.  The wire/HBM form (packed 3-bit codes) is
    produced by ``repro.core.codec.pack`` from ``codes()``.

    Grouping runs along axis 0 (the contraction dim for matmuls): for a
    weight of shape (K, ...), group g covers rows [g*G, (g+1)*G).
    """

    levels: jax.Array  # int8, same shape as the QUANTIZATION VIEW
    scales: jax.Array  # f32, shape (K // G, *view.shape[1:])
    group_size: int
    phi: int
    # For 4-D conv weights the view is channel-major (paper Fig. 5: vectors
    # run across input channels): (kh,kw,cin,cout) -> (cin, kh*kw*cout).
    # conv_shape stores the original shape for the inverse transpose.
    conv_shape: tuple | None = None

    # -- pytree plumbing -------------------------------------------------
    def tree_flatten(self):
        return (self.levels, self.scales), (self.group_size, self.phi, self.conv_shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        levels, scales = children
        return cls(levels=levels, scales=scales, group_size=aux[0], phi=aux[1],
                   conv_shape=aux[2] if len(aux) > 2 else None)

    # -- views -----------------------------------------------------------
    @property
    def shape(self):
        return self.levels.shape

    def codes(self) -> jax.Array:
        """Signed levels -> 3-bit codes per Table II (uint8 in [0, 7))."""
        return levels_to_codes(self.levels)

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        return dequantize(self, dtype=dtype)

    def nbits(self, scalar_bits: int = 32) -> int:
        """Total stored bits (Eq. 12 generalized to arbitrary tensors)."""
        return int(
            bits_per_code(self.phi) * np.prod(self.shape)
            + scalar_bits * np.prod(self.scales.shape)
        )


def levels_to_codes(levels: jax.Array) -> jax.Array:
    """Map signed level values {0,+-1,+-2,+-4} -> Table II 3-bit codes."""
    mag = jnp.abs(levels).astype(jnp.int32)
    # |level| -> magnitude index: 0->0, 1->1, 2->2, 4->3
    mag_idx = jnp.where(mag == 4, 3, mag)
    neg = (levels < 0).astype(jnp.int32)
    # positive codes are 0..3; negative codes are 4..6 (= 3 + mag_idx)
    return jnp.where(neg == 1, mag_idx + 3, mag_idx).astype(jnp.uint8)


def codes_to_levels(codes: jax.Array) -> jax.Array:
    """Inverse of :func:`levels_to_codes` via Table II.

    Matches the kernel decoder (`kernels.qsq_matmul._decode_codes`) on every
    3-bit pattern: the unused code 7 decodes to 0, and any stray high bits
    (corrupt/unmasked input) are dropped before the table lookup instead of
    clamping to the last table entry.
    """
    return jnp.asarray(LEVEL_TABLE)[codes.astype(jnp.int32) & 0x7]


# Sign-magnitude recode (wire format v2): bit 2 is the sign, bits 1..0 the
# magnitude index (0->0, 1->1, 2->2, 3->4).  Unlike Table II's offset code,
# masking the low bit-planes degrades + and - levels alike, so a truncated
# plane stream is sign-symmetric by construction.  Code 4 (-0) is unused.
SM_LEVEL_TABLE = np.array([0, 1, 2, 4, 0, -1, -2, -4], dtype=np.int8)


def levels_to_smcodes(levels: jax.Array) -> jax.Array:
    """Map signed levels {0,+-1,+-2,+-4} -> sign-magnitude 3-bit codes."""
    mag = jnp.abs(levels).astype(jnp.int32)
    mag_idx = jnp.where(mag == 4, 3, mag)
    neg = (levels < 0).astype(jnp.int32)
    return (mag_idx + 4 * neg).astype(jnp.uint8)


def smcodes_to_levels(codes: jax.Array) -> jax.Array:
    """Inverse of :func:`levels_to_smcodes`; -0 (code 4) decodes to 0."""
    return jnp.asarray(SM_LEVEL_TABLE)[codes.astype(jnp.int32) & 0x7]


def _grouped(w: jax.Array, group_size: int) -> jax.Array:
    """Reshape (K, ...) -> (K//G, G, ...) with validation."""
    k = w.shape[0]
    if k % group_size != 0:
        raise ValueError(
            f"leading dim {k} not divisible by group_size {group_size}"
        )
    return w.reshape(k // group_size, group_size, *w.shape[1:])


def _nearest_levels(wg, alpha_b, max_level):
    """argmin_beta |w - alpha*beta| over the signed power-of-two alphabet."""
    r = wg / alpha_b
    a = jnp.abs(r)
    mag = jnp.where(
        a < 0.5, 0, jnp.where(a < 1.5, 1, jnp.where(a < 3.0, 2, 4))
    ).astype(jnp.int8)
    mag = jnp.minimum(mag, max_level).astype(jnp.int8)
    return jnp.where(r < 0, -mag, mag).astype(jnp.int8)


@partial(jax.jit, static_argnames=("phi", "group_size", "assign", "delta",
                                   "gamma_frac", "refit_alpha"))
def _quantize_impl(
    w: jax.Array,
    *,
    phi: int,
    group_size: int,
    assign: str,
    delta: float,
    gamma_frac: float,
    refit_alpha: bool = False,
):
    wg = _grouped(w.astype(jnp.float32), group_size)  # (NG, G, ...)

    # Eq. 9:  alpha = sum |w| / (phi * N)    (per group)
    alpha = jnp.sum(jnp.abs(wg), axis=1) / (phi * group_size)  # (NG, ...)
    safe_alpha = jnp.where(alpha == 0, 1.0, alpha)
    alpha_b = safe_alpha[:, None]  # broadcast over the group axis

    max_level = 2 ** (theta_levels(phi) - 2) if phi > 1 else 1

    if assign == "nearest":
        levels = _nearest_levels(wg, alpha_b, max_level)
    elif assign == "sigma":
        # Eq. 10: thresholds from sigma_P / sigma_N (RMS of the positive /
        # negative halves of the group; RMS-about-zero is the robust reading
        # of the paper's "standard deviation of the vector containing
        # positive/negative filter values").
        pos_mask = wg > 0
        neg_mask = wg < 0
        eps = 1e-12
        sig_p = jnp.sqrt(
            jnp.sum(jnp.where(pos_mask, wg * wg, 0.0), axis=1)
            / (jnp.sum(pos_mask, axis=1) + eps)
        )[:, None]
        sig_n = jnp.sqrt(
            jnp.sum(jnp.where(neg_mask, wg * wg, 0.0), axis=1)
            / (jnp.sum(neg_mask, axis=1) + eps)
        )[:, None]
        gamma = gamma_frac * alpha_b
        a = jnp.abs(wg)
        sig = jnp.where(wg >= 0, sig_p, sig_n)
        sig = jnp.where(sig == 0, alpha_b, sig)  # degenerate group fallback
        mag = jnp.where(
            a < gamma,
            0,
            jnp.where(a < sig, 1, jnp.where(a < delta * sig, 2, 4)),
        ).astype(jnp.int8)
        mag = jnp.minimum(mag, max_level).astype(jnp.int8)
        levels = jnp.where(wg < 0, -mag, mag).astype(jnp.int8)
    else:  # pragma: no cover - guarded by QSQConfig
        raise ValueError(f"unknown assign mode {assign!r}")

    alpha_out = alpha
    if refit_alpha:
        # one Lloyd iteration: least-squares alpha for the current levels,
        # then re-assign against the refitted alpha (beyond-paper, same wire
        # format).  Guard degenerate groups (all-zero levels).
        for _ in range(2):
            lev_f = levels.astype(jnp.float32)
            num = jnp.sum(wg * lev_f, axis=1)
            den = jnp.sum(lev_f * lev_f, axis=1)
            alpha_out = jnp.where(den > 0, num / jnp.maximum(den, 1e-12), safe_alpha)
            alpha_out = jnp.abs(alpha_out)
            safe2 = jnp.where(alpha_out == 0, 1.0, alpha_out)[:, None]
            levels = _nearest_levels(wg, safe2, max_level)

    levels = levels.reshape(w.shape)
    return levels, alpha_out.astype(jnp.float32)


def quantize(w: jax.Array, cfg: QSQConfig) -> QSQTensor:
    """Quantize a tensor along its leading axis in groups of ``cfg.group_size``."""
    levels, scales = _quantize_impl(
        w,
        phi=cfg.phi,
        group_size=cfg.group_size,
        assign=cfg.assign,
        delta=cfg.delta,
        gamma_frac=cfg.gamma_frac,
        refit_alpha=cfg.refit_alpha,
    )
    return QSQTensor(levels=levels, scales=scales, group_size=cfg.group_size, phi=cfg.phi)


def dequantize(q: QSQTensor, dtype=jnp.float32) -> jax.Array:
    """w_hat = alpha * beta  (Table II shift-and-scale decode, as arithmetic)."""
    lev = _grouped(q.levels.astype(jnp.float32), q.group_size)
    out = lev * q.scales[:, None]
    return out.reshape(q.levels.shape).astype(dtype)


def quantization_error(w: jax.Array, q: QSQTensor) -> jax.Array:
    """Eq. 5 objective value ||w - alpha*beta||^2 (total, f32)."""
    return jnp.sum((w.astype(jnp.float32) - q.dequantize()) ** 2)


def zeros_fraction(x: jax.Array) -> jax.Array:
    """Fraction of exactly-zero entries (paper reports +6% zeros after QSQ)."""
    return jnp.mean((x == 0).astype(jnp.float32))


def exhaustive_threshold_search(
    w: jax.Array,
    cfg: QSQConfig,
    deltas=(1.5, 2.0, 2.5, 3.0),
    gamma_fracs=(0.25, 0.5, 0.75),
) -> QSQConfig:
    """The paper's 'thresholds determined by exhaustive search' (sec III.A).

    Minimizes the Eq. 5 reconstruction error over a small (delta, gamma) grid
    for the sigma assignment mode.  Returns the best config.
    """
    best, best_err = cfg, float("inf")
    for d in deltas:
        for g in gamma_fracs:
            cand = dataclasses.replace(cfg, assign="sigma", delta=d, gamma_frac=g)
            err = float(quantization_error(w, quantize(w, cand)))
            if err < best_err:
                best, best_err = cand, err
    return best

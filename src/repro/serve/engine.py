"""Batched serving engine — serves directly from the 3-bit wire.

Engines are normally built through the quality-dial facade
(:func:`repro.api.compress` -> ``EdgeArtifact.engine(quality=...)``): the
wire path is the paper's edge flow — the 3-bit + scalar artifact crosses
the channel and is served WITHOUT a full-tree dequantize.  Matmul weights
stay packed (:class:`~repro.quant.store.PackedWeight` bit-planes) end to
end and are decoded tile-by-tile inside the fused Pallas dequant-matmul,
so serving actually realizes the 3.2-4.6x weight-HBM cut the kernel was
built for.  Only non-matmul leaves (embeddings, norms, attention output
projections, convs) are decoded once at load, per the QuantPolicy
exclusions.  ``set_quality`` re-dials an artifact-built engine to another
tier in place — LSB plane truncation on the already-loaded wire, never a
re-quantize.

Serving is REQUEST-LEVEL continuously batched (attention families):
``submit()`` enqueues a prompt, each ``step()`` admits queued requests
into FREE slots — one single-slot prefill (the one-dispatch causal
forward on a zeroed batch-1 cache) plus a traced cache-lane insert per
admission — then runs ONE fixed-width greedy decode iteration over all
lanes.  Per-slot cache positions and an ``active`` mask make finished and
empty slots dead lanes rather than shape changes, so admissions and
evictions never retrace, and a new prompt starts decoding next step
instead of waiting for the whole batch to drain.  Finished requests are
evicted in the same step and surface through ``poll()`` /
``run_until_drained()``.

Quality is PER-REQUEST on artifact-built packed continuous engines:
``submit(prompt, max_new, quality="lo")`` admits the request at its own
tier, and the mixed-tier batch shares the one decode dispatch — each
packed matmul takes a per-row plane mask derived from the per-slot tier
indices (``PackedWeight.tier_drops``), so every lane's tokens are
bit-identical to a single-tier engine serving that prompt alone at that
tier, and tier changes are mask flips (no retrace, no param-tree swap).
``set_quality`` then only moves the default tier for quality-less
submissions.

The stream is OVERLOAD-GRACEFUL: ``submit(..., deadline=...)`` puts the
request on a cost-clock budget — each dispatch advances the stream clock
by its weight-read fraction (a full-quality forward costs 1.0, a
demand-shortened one its ``read_frac``), so the clock ticks in
HBM-bandwidth units, the resource the paper's plane truncation buys
back.  Past-deadline requests are TIMED_OUT: popped from the queue, or
evicted mid-decode by an active-mask flip (zero retrace; survivors are
bit-identical; any tokens already emitted remain as a partial result).
``cancel(rid)`` is the caller-initiated twin.  A pluggable
:class:`~repro.serve.admission.AdmissionPolicy` (``ServeConfig.admission``)
can downgrade incoming tiers — degrade quality instead of latency —
before shedding, and ``ServeConfig.max_queue`` bounds the queue; every
outcome surfaces as a typed
:class:`~repro.serve.scheduler.FinishReason` through the structured
:meth:`poll`.

``generate()`` is a thin submit-all/drain wrapper over that scheduler for
greedy attention-family engines, and otherwise falls back to the static
two-program path (one-dispatch prefill + multi-token decode scan, or the
temperature-sampled scan when ``ServeConfig.temperature > 0``).  The
wrapper trades the static scan's single host sync for one sync per
step() — the cost of a schedulable decode loop; throughput-bound batch
decoding with no arrival stream can set ``ServeConfig(continuous=False)``
to keep the one-scan path (tokens are identical either way).

Dense families keep the exactness guarantee: per-slot left padding and
active masking mean a prompt's tokens are invariant to its batch mates
AND to when they were admitted.  MoE keeps the weaker guarantee the
static batch had — all lanes share expert capacity, and under the
scheduler that includes DEAD lanes (a FREE/DONE slot's frozen token
still routes through the experts), so an MoE request's tokens can shift
with slot history under capacity overflow, exactly as they could with
live batch mates.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import dispatch
from repro.models.api import Model
from repro.models.base import init_params
from repro.serve.admission import ADMIT, REJECT, SHED, AdmissionPolicy, LoadView
from repro.serve.scheduler import (
    FinishReason,
    Request,
    RequestStatus,
    Scheduler,
    SpecConfig,
    SubmitRejected,
    plane_demand,
)
from repro.train.step import (
    make_admit_step,
    make_cache_prefill_step,
    make_cont_decode_step,
    make_decode_loop,
    make_sample_decode_loop,
    make_serve_step,
    make_verify_step,
    supports_fused_prefill,
)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_slots: int = 8
    max_len: int = 256    # continuous sessions: KV cache length per slot
    temperature: float = 0.0  # 0 => greedy; > 0 => categorical sampling
    packed: bool = True  # wire loads: keep matmul weights in bit-plane form
    continuous: bool = True  # greedy attention-family generate() -> scheduler
    max_prompt: int = 64  # continuous sessions: fixed prefill width
    max_queue: int | None = None  # bound on queued requests; None = unbounded
    # pluggable SLO admission control (see repro.serve.admission); None
    # admits everything at the requested tier, exactly the pre-SLO behavior
    admission: AdmissionPolicy | None = None


@dataclasses.dataclass(frozen=True)
class StepInfo:
    """What one :meth:`ServeEngine.step` did — host-side accounting only.

    ``cost`` is the step's advance of the stream cost clock (sum of its
    dispatches' weight-read fractions); ``demand`` the decode dispatch's
    static plane-demand floor (None when no lane was live)."""

    admitted: tuple[int, ...]
    finished: tuple[int, ...]
    timed_out: tuple[int, ...]
    live: int
    demand: int | None
    cost: float
    # speculative round accounting: draft-tier tokens proposed this step
    # and how many of them the verify dispatch accepted (0/0 for plain
    # decode steps)
    drafted: int = 0
    accepted: int = 0


class _Session:
    """Device-side state of one continuous-batching stream: the live
    multi-slot cache, the per-slot current tokens / active mask, and the
    host-side :class:`Scheduler`.  All shapes are fixed at construction
    ((slots, cache_len) cache, (1, prefill_len) admission prompts), so
    every jitted program traces once per session shape."""

    def __init__(self, model: Model, slots: int, prefill_len: int,
                 cache_len: int, max_queue: int | None = None):
        if prefill_len < 1:
            raise ValueError(f"prefill width must be >= 1, got {prefill_len}")
        if prefill_len >= cache_len:
            raise ValueError(
                f"cache_len {cache_len} leaves no decode room after the "
                f"{prefill_len}-token prefill window"
            )
        self.prefill_len = prefill_len
        self.cache_len = cache_len
        self.sched = Scheduler(slots, max_queue=max_queue)
        key = jax.random.PRNGKey(0)
        self.cache = init_params(key, model.cache_descs(slots, cache_len))
        # zeroed batch-1 cache reused (never donated) by every admission
        self.zero_slot_cache = init_params(key, model.cache_descs(1, cache_len))
        self.cur = np.zeros((slots, 1), np.int32)
        self.active = np.zeros((slots,), np.int32)
        # per-slot quality-tier index (per-request quality): set at
        # admission, a traced operand of the decode dispatch — tier
        # changes are data changes, never retraces
        self.tiers = np.zeros((slots,), np.int32)
        self.step_idx = 0
        # stream cost clock: advances by each dispatch's weight-read
        # fraction (full quality = 1.0); deadlines are enforced on it
        self.now = 0.0
        # demand-streaming meter: packed weight-plane words the stream's
        # dispatches read vs. what full-quality streaming would have read,
        # and the tokens those dispatches emitted (host-side analytic
        # accounting — the device program's reads are shaped by the same
        # static demand, so the two agree by construction)
        self.plane_words_read = 0
        self.plane_words_full = 0
        self.tokens_emitted = 0
        # self-speculative decoding meter: draft-tier tokens proposed vs.
        # accepted by verify dispatches across the stream's lifetime
        self.drafted = 0
        self.accepted = 0


class ServeEngine:
    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.cfg = cfg
        self.params = params
        self.n_packed_leaves = 0  # overwritten by the artifact/wire loaders
        self.artifact = None      # set by EdgeArtifact.engine (quality dial)
        self.quality: str | None = None
        # per-request quality: tier-name order matching the tier_drops
        # vectors stamped on the packed leaves (set by EdgeArtifact.engine
        # when the engine serves per-request tiers); None = single-tier
        self.tier_names: list[str] | None = None
        # degraded-wire ceiling: the best (lowest) tier index this engine
        # may serve.  0 = pristine artifact; EdgeArtifact.engine raises it
        # when trailing LSB planes failed their checksums, so requests are
        # silently clamped DOWN to what the surviving planes support
        # (requested tier stays visible in RequestStatus.requested)
        self.tier_ceiling: int = 0
        self.serve_step = jax.jit(make_serve_step(model))
        self._prefill = jax.jit(make_cache_prefill_step(model),
                                static_argnums=(5,))  # demand: see below
        self._decode_loop = jax.jit(make_decode_loop(model))
        self._sample_loop = None  # jitted lazily; most engines stay greedy
        # continuous-batching programs (attention families; traced lazily).
        # ``demand`` — the batch plane-demand floor — is a STATIC argument:
        # plane-major packed weights shorten their HBM reads per demand, so
        # each distinct demand is its own trace, bounded by the tier count
        self._cont_step = jax.jit(make_cont_decode_step(model),
                                  static_argnums=(5,))
        self._admit = jax.jit(make_admit_step(model), static_argnums=(7,))
        # speculative verify: one trace per (demand, window width) pair —
        # demand is bounded by the tier count, width by the draft k
        self._verify = jax.jit(make_verify_step(model), static_argnums=(7,))
        self._session: _Session | None = None
        self._plane_words_cache: dict[int, tuple[int, int]] = {}

    # -- loading -----------------------------------------------------------
    @classmethod
    def from_wire(cls, model: Model, wire_tree, cfg: ServeConfig):
        """Deprecated shim over :class:`repro.quant.artifact.EdgeArtifact`.

        Equivalent to ``EdgeArtifact(wire, model.cfg).engine("hi",
        serve_cfg=cfg)``: full-quality serving with kernel-eligible matmul
        weights re-packed to bit-planes (``cfg.packed``, default) or a full
        dense decode at load (``packed=False``).  New code should call
        ``repro.api.compress(...)`` and dial quality on the artifact.
        """
        warnings.warn(
            "ServeEngine.from_wire is deprecated; use repro.api.compress() "
            "/ EdgeArtifact.engine(quality=...) instead",
            DeprecationWarning, stacklevel=2,
        )
        from repro.quant.artifact import EdgeArtifact

        art = EdgeArtifact(wire=wire_tree, arch_config=model.cfg)
        return art.engine(quality="hi", serve_cfg=cfg)

    # -- quality dial ------------------------------------------------------
    @property
    def per_request_quality(self) -> bool:
        """True when this engine serves quality PER REQUEST: packed leaves
        carry per-tier plane-drop vectors, ``submit(..., quality=...)``
        admits each request at its own tier inside the one continuous
        decode dispatch, and :meth:`set_quality` is just the default for
        quality-less submissions (no drain, no param rebuild)."""
        return self.tier_names is not None

    def _clamp_ceiling(self, quality: str | None) -> str | None:
        """Degraded-wire clamp: tiers better than ``tier_ceiling`` would
        stream planes that failed their checksums — serve the ceiling
        tier instead (degrade, don't fail)."""
        if (self.tier_ceiling and self.tier_names is not None
                and quality is not None
                and self.tier_names.index(quality) < self.tier_ceiling):
            return self.tier_names[self.tier_ceiling]
        return quality

    def _resolve_quality(self, quality: str | None) -> str | None:
        """Validate a submit-time tier name (None -> the engine default)."""
        if quality is None:
            return self._clamp_ceiling(self.quality)
        if self.tier_names is None:
            raise ValueError(
                "per-request quality needs an engine with per-tier packed "
                "weights; build it via repro.api.compress(...).engine() "
                "(this engine serves a single tier)"
            )
        if quality not in self.tier_names:
            raise KeyError(
                f"unknown quality tier {quality!r}; this engine has "
                f"{self.tier_names}"
            )
        return self._clamp_ceiling(quality)

    def _tier_index(self, quality: str | None) -> int:
        if self.tier_names is None or quality is None:
            return 0
        return self.tier_names.index(quality)

    def set_quality(self, quality: str) -> "ServeEngine":
        """Dial the engine's quality tier.

        Per-request engines (built by ``EdgeArtifact.engine`` with packed
        continuous serving): the params already carry every tier — this
        just changes the DEFAULT tier for future quality-less
        ``submit``/``generate`` calls.  No drain, no reload, no retrace;
        live requests keep the tier they were admitted at.

        Single-tier engines re-resolve the param tree at the new tier of
        this engine's artifact, in place — plane truncation on the loaded
        wire, no reload and no re-quantization.  The jitted programs take
        params as arguments, so the dial costs one retrace, not a rebuild.
        A live continuous stream must drain first (its KV entries were
        computed at the old tier); an idle session is dropped."""
        if self.artifact is None:
            raise ValueError(
                "this engine was not built from an EdgeArtifact; construct "
                "it via repro.api.compress(...).engine(quality=...) to dial "
                "quality"
            )
        if self.per_request_quality:
            self.quality = self._resolve_quality(quality)
            return self
        if self.has_work:
            raise RuntimeError(
                "cannot re-dial quality while a continuous stream has live "
                "requests; run_until_drained() (or poll results) first"
            )
        self._session = None
        self._plane_words_cache.clear()  # params change: re-derive meter
        self.params, self.n_packed_leaves = self.artifact.serve_params(
            quality, packed=self.cfg.packed
        )
        self.quality = quality
        return self

    # -- continuous batching ------------------------------------------------
    def _continuous_capable(self) -> bool:
        return supports_fused_prefill(self.model)

    def _require_continuous(self):
        if self.cfg.temperature > 0:
            raise ValueError(
                "the continuous scheduler is greedy-only; build the engine "
                "with temperature=0 (generate() still samples via the "
                "static path)"
            )
        if not self._continuous_capable():
            raise ValueError(
                f"continuous batching needs an attention family with "
                f"per-lane KV isolation; {self.model.cfg.family!r} "
                f"(cross_every={self.model.cfg.cross_every}) serves via "
                f"generate()"
            )

    def _ensure_session(self) -> _Session:
        if self._session is None:
            self._session = _Session(
                self.model, self.cfg.batch_slots,
                prefill_len=self.cfg.max_prompt, cache_len=self.cfg.max_len,
                max_queue=self.cfg.max_queue,
            )
        return self._session

    def _admission_view(self, s: _Session) -> LoadView:
        """Snapshot the stream load for an :class:`AdmissionPolicy`:
        per-request (tier index, remaining dispatches) for queued and live
        work plus the per-tier dispatch cost table."""
        names = (tuple(self.tier_names) if self.tier_names is not None
                 else (self.quality or "default",))
        return LoadView(
            step=s.step_idx, now=s.now, n_slots=s.sched.n_slots,
            tier_names=names, tier_costs=self.tier_cost_table(),
            queued=tuple((self._tier_index(r.quality), r.max_new)
                         for r in s.sched.queue),
            live=tuple((self._tier_index(r.quality),
                        max(r.max_new - len(r.out), 0))
                       for r in s.sched.slot_req if r is not None),
        )

    def submit(self, prompt: Sequence[int], max_new: int = 32,
               quality: str | None = None,
               deadline: float | None = None,
               speculate: SpecConfig | None = None) -> int:
        """Enqueue one prompt on the engine's continuous stream; returns a
        request id for :meth:`poll`.  The request is admitted into the
        first slot that frees up — immediately on the next :meth:`step`
        if one is FREE — without flushing the requests already decoding.

        ``quality`` names the request's OWN tier (per-request engines): it
        is prefilled AND decoded at that tier inside the shared fixed-width
        dispatches, sharing the batch with requests at other tiers.  None
        takes the engine default (``set_quality``), resolved at submission
        time.

        ``deadline`` is a RELATIVE cost-clock budget (see :attr:`now`):
        once the stream clock has advanced that far the request is timed
        out wherever it is — queued (popped) or mid-decode (evicted by an
        active-mask flip, keeping its partial tokens).

        ``speculate`` turns on SELF-SPECULATIVE decoding for this request
        (:class:`~repro.serve.scheduler.SpecConfig`): the engine drafts
        ``k`` tokens per round at ``draft_tier`` — a cheaper plane mask
        over the same packed weights, streamed at the draft demand floor —
        then verifies the whole window in one dispatch at the request's
        serving tier, accepting the longest agreeing prefix and rolling
        the KV ``pos`` back over rejections.  Tokens are identical to
        plain decode at the serving tier; only the dispatch mix changes.
        The draft tier must sit strictly below the serving tier, and the
        engine must serve per-request quality on a full-length cache.

        Requests that can NEVER be served raise :class:`SubmitRejected`
        (a ValueError) — oversized prompt, cache overflow, non-positive
        deadline, unusable speculation config — instead of queueing a
        guaranteed hang.  LOAD-dependent refusals never raise: a full
        ``max_queue`` or an admission-policy shed returns a rid that is
        already terminal with ``finish_reason`` ``REJECTED``/``SHED``."""
        self._require_continuous()
        quality = self._resolve_quality(quality)
        requested = quality
        if speculate is not None:
            self._check_speculate(speculate, quality)
        s = self._ensure_session()
        if len(prompt) > s.prefill_len:
            raise SubmitRejected(
                f"prompt of {len(prompt)} tokens exceeds the stream's "
                f"fixed {s.prefill_len}-token prefill window; raise "
                f"ServeConfig.max_prompt"
            )
        if s.prefill_len + max_new > s.cache_len:
            raise SubmitRejected(
                f"prefill window {s.prefill_len} + max_new {max_new} "
                f"exceeds the {s.cache_len}-entry slot cache; raise "
                f"ServeConfig.max_len"
            )
        if deadline is not None and not deadline > 0:
            raise SubmitRejected(
                f"deadline must be a positive cost-clock budget, "
                f"got {deadline}"
            )
        if s.sched.queue_full:
            return s.sched.finish_unadmitted(
                prompt, max_new, s.step_idx, FinishReason.REJECTED,
                quality=quality, requested=requested, arrival_t=s.now,
                detail=f"bounded queue full (max_queue={s.sched.max_queue})",
            )
        if self.cfg.admission is not None:
            d = self.cfg.admission.decide(
                self._tier_index(quality), max_new, self._admission_view(s))
            if d.action == ADMIT:
                if d.tier is not None and self.tier_names is not None:
                    # quality-scalable shedding: serve a cheaper tier
                    # instead of queueing past the SLO
                    quality = self.tier_names[
                        max(int(d.tier), self.tier_ceiling)]
            elif d.action in (SHED, REJECT):
                reason = (FinishReason.SHED if d.action == SHED
                          else FinishReason.REJECTED)
                return s.sched.finish_unadmitted(
                    prompt, max_new, s.step_idx, reason, quality=quality,
                    requested=requested, arrival_t=s.now, detail=d.detail,
                )
            else:
                raise ValueError(
                    f"admission policy returned unknown action {d.action!r}")
        abs_deadline = None if deadline is None else s.now + float(deadline)
        return s.sched.submit(prompt, max_new, arrival=s.step_idx,
                              quality=quality, requested=requested,
                              deadline=abs_deadline, arrival_t=s.now,
                              speculate=speculate)

    def _check_speculate(self, sc: SpecConfig, quality: str | None) -> None:
        """Reject speculation configs that could never save anything:
        guaranteed-useless setups fail loud at submit, while a mere
        admission-policy downgrade to the draft tier later just disables
        drafting for the affected rounds."""
        if not self.per_request_quality:
            raise SubmitRejected(
                "speculative decoding drafts at a cheaper tier of the same "
                "packed weights, which needs a per-request-quality engine; "
                "build it via repro.api.compress(...).engine()"
            )
        if self.model.cfg.window is not None:
            raise SubmitRejected(
                "speculative decoding needs a full-length KV cache; this "
                "model's sliding-window ring buffer cannot roll back "
                "rejected entries"
            )
        if sc.k < 1:
            raise SubmitRejected(
                f"speculate.k must be >= 1 drafted tokens, got {sc.k}")
        if sc.draft_tier not in self.tier_names:
            raise SubmitRejected(
                f"unknown draft tier {sc.draft_tier!r}; this engine has "
                f"{self.tier_names}"
            )
        if self.tier_names.index(sc.draft_tier) <= self._tier_index(quality):
            raise SubmitRejected(
                f"draft tier {sc.draft_tier!r} is not below serving tier "
                f"{quality!r} on the ladder {self.tier_names}; drafting "
                f"there could never save weight reads"
            )

    def cancel(self, rid: int) -> RequestStatus:
        """Caller-initiated abort.  A queued request is removed; a live one
        is evicted mid-decode — an active-mask flip, zero retrace, its
        partial tokens kept.  Idempotent: an already-terminal rid returns
        its (unchanged) status; unknown rids raise KeyError."""
        if self._session is None:
            raise KeyError(f"unknown request id {rid} (no active stream)")
        s = self._session
        _, slot = s.sched.cancel(rid, s.step_idx, s.now)
        if slot is not None:
            s.active[slot] = 0  # dead lane: a data change, never a retrace
        return s.sched.status(rid)

    def _forward_plane_words(self, demand: int) -> tuple[int, int]:
        """(words_read, words_full): packed weight-plane int32 words ONE
        full forward streams at static plane-demand floor ``demand``, vs.
        what it would stream reading every plane.  Analytic — derived from
        the packed leaves' shapes and per-tier drop vectors, the same
        quantities the demand-routed kernels shape their HBM reads by.
        Interleaved leaves always stream all three planes (masking happens
        post-load); plane-major leaves shorten the read."""
        from repro.quant.store import PackedWeight

        cached = self._plane_words_cache.get(demand)
        if cached is not None:
            return cached
        read = full = 0
        for leaf in jax.tree_util.tree_leaves(
            self.params, is_leaf=lambda x: isinstance(x, PackedWeight)
        ):
            if not isinstance(leaf, PackedWeight):
                continue
            words = leaf.planes.size // 3  # int32 words per plane
            full += 3 * words
            n_read = (3 - leaf.demand_drop(demand)
                      if leaf.plane_major else 3)
            read += n_read * words
        self._plane_words_cache[demand] = (read, full)
        return read, full

    def _dispatch_cost(self, demand: int) -> float:
        """One dispatch's advance of the stream cost clock: its weight
        read fraction at ``demand`` (packed weights dominate decode time
        on the HBM-bandwidth model the plane-streaming kernels optimize;
        a full-quality dispatch is the 1.0 reference).  Engines with no
        packed leaves tick 1.0 per dispatch — a plain step counter."""
        read, full = self._forward_plane_words(demand)
        return read / full if full else 1.0

    def tier_cost_table(self) -> tuple[float, ...]:
        """Per-tier dispatch cost (weight-read fraction at each tier's
        demand floor), indexed like ``tier_names`` — the cost side of the
        admission policy's quality/cost knapsack.  Single-tier engines
        get the one-entry table ``(1.0,)``."""
        n = len(self.tier_names) if self.tier_names is not None else 1
        return tuple(self._dispatch_cost(t) for t in range(n))

    def stream_stats(self) -> dict:
        """Demand-streaming meter for the current continuous stream:
        ``tokens`` emitted, packed weight-plane ``bytes_read`` the stream's
        dispatches streamed, ``bytes_full`` a full-quality stream would
        have, and ``bytes_per_token`` — the bench_serve headline number."""
        s = self._session
        if s is None or s.tokens_emitted == 0:
            return {"tokens": 0, "bytes_read": 0, "bytes_full": 0,
                    "bytes_per_token": 0.0, "read_frac": 1.0,
                    "drafted": 0, "accepted": 0, "acceptance_rate": 0.0}
        bytes_read = 4 * s.plane_words_read
        bytes_full = 4 * s.plane_words_full
        return {
            "tokens": s.tokens_emitted,
            "bytes_read": bytes_read,
            "bytes_full": bytes_full,
            # every emitted token is an accepted (verify-tier-exact) token,
            # so for speculative streams this IS bytes per accepted token:
            # draft reads land in the numerator, rejected drafts never
            # reach the denominator
            "bytes_per_token": bytes_read / s.tokens_emitted,
            "read_frac": bytes_read / bytes_full if bytes_full else 1.0,
            "drafted": s.drafted,
            "accepted": s.accepted,
            "acceptance_rate": (s.accepted / s.drafted
                                if s.drafted else 0.0),
        }

    def step(self) -> StepInfo:
        """One scheduler iteration: enforce deadlines (pop expired queued
        requests; evict expired live ones by active-mask flip), admit
        queued requests into FREE slots (single-slot prefill + cache lane
        insert each, emitting the request's first token from the prefill
        logits), then ONE decode dispatch over all lanes at fixed width.
        Requests that reach ``max_new`` are evicted — their slot is FREE
        for the next step's admissions — and surface via :meth:`poll`.

        Weight-plane reads are DEMAND-DRIVEN: each admission prefills at
        the request's own tier (its demand floor), and the decode dispatch
        streams at the batch floor — the min live tier index
        (:func:`~repro.serve.scheduler.plane_demand`) — so a lo-tier-heavy
        batch reads a fraction of the weight bytes.  Demand is a static
        jit argument; at most one retrace per distinct tier.  The stream
        cost clock (:attr:`now`) advances by the step's summed dispatch
        read fractions — cheaper tiers genuinely buy back clock time."""
        s = self._ensure_session()
        admitted: list[int] = []
        finished: list[int] = []
        timed_out: list[int] = []
        cost = 0.0
        for req in s.sched.expire_queued(s.step_idx, s.now):
            timed_out.append(req.rid)
        for slot in s.sched.expired_decoding(s.now):
            req = s.sched.release(slot, s.step_idx, s.now,
                                  FinishReason.TIMED_OUT)
            s.active[slot] = 0  # dead lane: a data change, never a retrace
            timed_out.append(req.rid)
        for slot, req in s.sched.admissible():
            s.sched.activate(slot, req, s.step_idx, now=s.now)
            s.tiers[slot] = self._tier_index(req.quality)
            admitted.append(req.rid)
            toks = np.zeros((1, s.prefill_len), np.int32)
            toks[0, s.prefill_len - len(req.tokens):] = req.tokens
            # one dispatch: prefill + lane insert + on-device argmax; the
            # host syncs on a single int32, not a (vocab,) logits row.
            # The prefill runs at the REQUEST's tier (per-row plane masks)
            # and streams only the planes that tier demands.
            demand = int(s.tiers[slot])
            s.cache, first = self._admit(
                self.params, s.zero_slot_cache, s.cache, jnp.asarray(toks),
                jnp.asarray([len(req.tokens)], jnp.int32), jnp.int32(slot),
                jnp.asarray(s.tiers[slot:slot + 1]), demand,
            )
            r, f = self._forward_plane_words(demand)
            s.plane_words_read += r
            s.plane_words_full += f
            s.tokens_emitted += 1
            cost += self._dispatch_cost(demand)
            first = int(first)
            s.sched.start_decoding(slot)
            s.cur[slot, 0] = first
            if s.sched.record(slot, first, s.step_idx, now=s.now):
                s.sched.evict(slot)  # max_new == 1: done at admission
                finished.append(req.rid)
            else:
                s.active[slot] = 1
        live = s.sched.decoding_slots()
        demand_used: int | None = None
        drafted_n = accepted_n = 0
        # speculating slots this round: slot -> (k_eff, draft tier index).
        # k is clamped so a round never drafts past max_new (the verify
        # bonus token is the +1), and drafting is a no-op for requests
        # whose serving tier was downgraded to (or below) the draft tier.
        spec: dict[int, tuple[int, int]] = {}
        for slot in live:
            req = s.sched.slot_req[slot]
            if req.speculate is None:
                continue
            didx = self.tier_names.index(req.speculate.draft_tier)
            if didx <= int(s.tiers[slot]):
                continue
            k_eff = min(req.speculate.k, req.max_new - len(req.out) - 1)
            if k_eff >= 1:
                spec[slot] = (k_eff, didx)
        if spec:
            demand_used, rcost, drafted_n, accepted_n = self._spec_round(
                s, spec, finished)
            cost += rcost
        elif live:
            demand = plane_demand(s.tiers[slot] for slot in live)
            demand_used = demand
            nxt, s.cache = self._cont_step(
                self.params, s.cache, jnp.asarray(s.cur),
                jnp.asarray(s.active), jnp.asarray(s.tiers), demand,
            )
            r, f = self._forward_plane_words(demand)
            s.plane_words_read += r
            s.plane_words_full += f
            s.tokens_emitted += len(live)
            cost += self._dispatch_cost(demand)
            nxt = np.asarray(nxt)  # the step's one host sync
            for slot in live:
                s.cur[slot, 0] = nxt[slot]
                rid = s.sched.slot_req[slot].rid
                if s.sched.record(slot, int(nxt[slot]), s.step_idx,
                                  now=s.now):
                    s.sched.evict(slot)
                    s.active[slot] = 0
                    finished.append(rid)
        s.step_idx += 1
        s.now += cost
        return StepInfo(admitted=tuple(admitted), finished=tuple(finished),
                        timed_out=tuple(timed_out), live=len(live),
                        demand=demand_used, cost=cost,
                        drafted=drafted_n, accepted=accepted_n)

    def _spec_round(self, s: _Session, spec: dict[int, tuple[int, int]],
                    finished: list[int]) -> tuple[int, float, int, int]:
        """One self-speculative draft/verify round over the live lanes.

        DRAFT: k ticks of the same jitted decode program plain serving
        uses — no new trace — with the speculating lanes' tier entries
        temporarily set to their draft tier, so the batch demand floor
        streams only the draft planes.  Non-speculating live lanes decode
        normally inside the same dispatches (per-row plane masks keep
        them exact) and their tokens are recorded each tick; drafted
        tokens are buffered host-side and the draft-tier KV they write is
        scratch.  Lanes whose k_eff is shorter than the round's go
        draft-inactive early — a mask flip.

        VERIFY: ONE batched dispatch at the lanes' serving tiers scores
        every window position, overwriting the scratch KV in place, and
        accepts each lane's longest agreeing prefix on device.  The lane
        emits its accepted drafts plus the verify pass's bonus token —
        always >= 1 token, every one exactly what plain serving-tier
        decode would have produced — and rejected entries cost one
        per-slot ``pos`` rollback (a data change inside the verify
        program; no retrace anywhere in the round).

        The cost clock is charged honestly: each draft tick advances it
        by the draft demand floor's read fraction, the verify by ONE
        serving-tier dispatch — not k — so deadlines and SLO admission
        stay denominated in actual weight reads.

        Returns (verify demand, round cost, drafted, accepted)."""
        k_round = max(k for k, _ in spec.values())
        # pos invariant: every live lane has prefill_len + emitted - 1
        # cache entries (admission leaves pos at the prefill width with
        # one token emitted; every emitted token since advanced it by 1)
        start = {slot: s.prefill_len + len(s.sched.slot_req[slot].out) - 1
                 for slot in spec}
        anchor = {slot: int(s.cur[slot, 0]) for slot in spec}
        drafts: dict[int, list[int]] = {slot: [] for slot in spec}
        cost = 0.0
        for j in range(k_round):
            draft_active = s.active.copy()
            draft_tiers = s.tiers.copy()
            for slot, (k_eff, didx) in spec.items():
                draft_active[slot] = 1 if j < k_eff else 0
                draft_tiers[slot] = didx
            live_now = [slot for slot in range(s.sched.n_slots)
                        if draft_active[slot]]
            if not live_now:
                break  # every non-spec lane finished and k_effs exhausted
            demand = plane_demand(int(draft_tiers[slot])
                                  for slot in live_now)
            with dispatch.dispatch_phase("draft"):
                nxt, s.cache = self._cont_step(
                    self.params, s.cache, jnp.asarray(s.cur),
                    jnp.asarray(draft_active), jnp.asarray(draft_tiers),
                    demand,
                )
            r, f = self._forward_plane_words(demand)
            s.plane_words_read += r
            s.plane_words_full += f
            cost += self._dispatch_cost(demand)
            nxt = np.asarray(nxt)
            for slot in live_now:
                s.cur[slot, 0] = int(nxt[slot])
                if slot in spec:
                    drafts[slot].append(int(nxt[slot]))  # proposed, not emitted
                else:
                    s.tokens_emitted += 1
                    rid = s.sched.slot_req[slot].rid
                    if s.sched.record(slot, int(nxt[slot]), s.step_idx,
                                      now=s.now):
                        s.sched.evict(slot)
                        s.active[slot] = 0
                        finished.append(rid)
        w = k_round + 1
        window = np.zeros((s.sched.n_slots, w), np.int32)
        wlen = np.zeros((s.sched.n_slots,), np.int32)
        smask = np.zeros((s.sched.n_slots,), np.int32)
        starts = np.zeros((s.sched.n_slots,), np.int32)
        for slot, (k_eff, _) in spec.items():
            window[slot, 0] = anchor[slot]
            window[slot, 1:1 + k_eff] = drafts[slot]
            wlen[slot] = k_eff + 1
            smask[slot] = 1
            starts[slot] = start[slot]
        vdemand = plane_demand(int(s.tiers[slot]) for slot in spec)
        with dispatch.dispatch_phase("verify"):
            toks, acc, s.cache = self._verify(
                self.params, s.cache, jnp.asarray(window),
                jnp.asarray(starts), jnp.asarray(wlen), jnp.asarray(smask),
                jnp.asarray(s.tiers), vdemand,
            )
        r, f = self._forward_plane_words(vdemand)
        s.plane_words_read += r
        s.plane_words_full += f
        cost += self._dispatch_cost(vdemand)
        toks = np.asarray(toks)
        acc = np.asarray(acc)  # the round's final host sync
        drafted_n = accepted_n = 0
        for slot, (k_eff, _) in spec.items():
            a = int(acc[slot])
            req = s.sched.slot_req[slot]
            req.drafted += k_eff
            req.accepted += a
            drafted_n += k_eff
            accepted_n += a
            s.cur[slot, 0] = int(toks[slot, a])  # bonus token: the new cur
            s.tokens_emitted += a + 1
            rid = req.rid
            done = False
            for tok in toks[slot, :a + 1]:
                done = s.sched.record(slot, int(tok), s.step_idx, now=s.now)
            if done:  # a+1 <= remaining, so only the last token can finish
                s.sched.evict(slot)
                s.active[slot] = 0
                finished.append(rid)
        s.drafted += drafted_n
        s.accepted += accepted_n
        return vdemand, cost, drafted_n, accepted_n

    def poll(self, rid: int | None = None):
        """Structured request status (see
        :class:`~repro.serve.scheduler.RequestStatus`).

        ``poll(rid)`` -> that request's status, an IDEMPOTENT read for any
        issued rid: ``.state`` says where it is
        (queued/prefilling/decoding/done), ``.finish_reason`` how it ended
        (``None`` means keep stepping), ``.tokens`` the emitted ids once
        terminal — partial for TIMED_OUT/CANCELLED, empty for
        SHED/REJECTED.  ``poll()`` -> {rid: status} for every request that
        TERMINATED since the last bare poll, handed out once (claimed
        results stay readable via ``poll(rid)`` /
        :attr:`completed_requests`).  Unknown rids raise KeyError."""
        if self._session is None:
            if rid is None:
                return {}
            raise KeyError(f"unknown request id {rid} (no active stream)")
        return self._session.sched.poll(rid)

    # -- stream introspection (the public view of the session state) -------
    @property
    def has_work(self) -> bool:
        """True while the stream has queued, prefilling or decoding
        requests."""
        return self._session is not None and self._session.sched.has_work

    @property
    def step_count(self) -> int:
        """Number of step() iterations the current stream has run."""
        return 0 if self._session is None else self._session.step_idx

    @property
    def now(self) -> float:
        """The stream cost clock: cumulative dispatch weight-read
        fractions (a full-quality dispatch = 1.0).  Deadlines and
        admission SLO budgets are denominated in this unit."""
        return 0.0 if self._session is None else self._session.now

    @property
    def queue_depth(self) -> int:
        """Requests waiting for a slot (admission queue length)."""
        return 0 if self._session is None else len(self._session.sched.queue)

    def advance_clock(self, dt: float) -> float:
        """Advance the stream cost clock by ``dt`` without dispatching —
        models idle wall-time between arrivals and injected slow ticks
        (fault harness), so deadlines keep aging while the engine waits.
        Returns the new :attr:`now`."""
        if dt < 0:
            raise ValueError(f"cannot rewind the cost clock (dt={dt})")
        s = self._ensure_session()
        s.now += float(dt)
        return s.now

    @property
    def completed_requests(self) -> dict[int, Request]:
        """Every finished Request of the current stream (rid -> Request,
        with arrival/admitted/finished step indices for latency stats);
        unlike poll(), repeated reads see the same map."""
        return {} if self._session is None else dict(self._session.sched.completed)

    @property
    def live_requests(self) -> list[Request]:
        """Requests currently occupying slots (PREFILLING/DECODING)."""
        if self._session is None:
            return []
        return [r for r in self._session.sched.slot_req if r is not None]

    def reset_stream(self) -> None:
        """Drop the continuous stream unconditionally — queued and live
        requests are abandoned, the next submit() starts a fresh session."""
        self._session = None

    def run_until_drained(self, max_ticks: int | None = None):
        """step() until the queue and every slot are empty; returns
        everything :meth:`poll` would (statuses of requests that
        terminated since the last poll, keyed by request id).

        ``max_ticks`` is a WATCHDOG, not a deadline: every step with work
        emits at least one token (admissions emit their first token in
        the same step), so a drain can never legitimately exceed the
        outstanding token count — the default bound is twice that plus
        slack, and overrunning it raises RuntimeError instead of spinning
        forever on a stuck stream."""
        s = self._ensure_session()
        if max_ticks is None:
            outstanding = sum(r.max_new for r in s.sched.queue)
            outstanding += sum(max(r.max_new - len(r.out), 1)
                               for r in s.sched.slot_req if r is not None)
            max_ticks = 2 * outstanding + s.sched.n_slots + 16
        n = 0
        while s.sched.has_work:
            if n >= max_ticks:
                raise RuntimeError(
                    f"run_until_drained watchdog: stream not drained after "
                    f"{n} ticks ({len(s.sched.queue)} queued, "
                    f"{len(s.sched.decoding_slots())} decoding); every tick "
                    f"should retire tokens — this stream is stuck"
                )
            self.step()
            n += 1
        return self.poll()

    # -- generation ----------------------------------------------------------
    def generate(self, prompts: Sequence[Sequence[int]], max_new: int = 32,
                 seed: int = 0, qualities=None):
        """Decode a batch of token-id prompts.  Returns lists of ids.

        Greedy attention-family engines route through the continuous
        scheduler (submit all, drain) — a pure wrapper, token-identical to
        the static program for dense families.  Sampling engines
        (``cfg.temperature > 0``), recurrent/cross families, and
        ``cfg.continuous=False`` take the static two-program path:
        one-dispatch prefill + one decode scan, sampling from
        ``softmax(logits / temperature)`` with a PRNG derived from
        ``seed`` (same seed + prompts => same tokens).

        ``qualities`` (per-request engines, continuous path only) assigns
        each prompt its own tier: a name applied to all, or one name per
        prompt — the whole mixed-tier batch shares the one decode dispatch.
        """
        if len(prompts) == 0:
            return []
        if any(len(p) == 0 for p in prompts):
            raise ValueError("every prompt must contain at least one token")
        b = len(prompts)
        slots = self.cfg.batch_slots
        if b > slots:
            raise ValueError(
                f"{b} prompts exceed the engine's {slots} batch_slots; "
                f"raise ServeConfig.batch_slots, split the batch, or "
                f"submit() to the continuous stream (which queues)"
            )
        if max_new < 1:
            # legacy contract on every path: zero-length decode is a no-op
            return [[] for _ in prompts]
        if isinstance(qualities, str):
            qualities = [qualities] * b
        if qualities is not None and len(qualities) != b:
            raise ValueError(
                f"{len(qualities)} qualities for {b} prompts; pass one tier "
                f"name per prompt (or a single name for all)"
            )
        if (self.cfg.continuous and self.cfg.temperature == 0
                and self._continuous_capable()):
            return self._generate_continuous(prompts, max_new, qualities)
        if qualities is not None:
            raise ValueError(
                "per-request qualities need the continuous scheduler path "
                "(greedy attention family, ServeConfig(continuous=True)); "
                "use set_quality() to dial this engine as a whole"
            )
        return self._generate_static(prompts, max_new, seed)

    def _generate_continuous(self, prompts, max_new: int, qualities=None):
        """Submit-all/drain on a throwaway session sized to this batch
        (prefill width = longest prompt, cache = prompt + max_new), so the
        traced shapes match the call exactly like the static path's.  The
        throwaway session is UNBOUNDED (no max_queue): the batch API has
        no arrival stream to shed."""
        maxp = max(len(p) for p in prompts)
        saved = self._session
        self._session = _Session(
            self.model, self.cfg.batch_slots,
            prefill_len=maxp, cache_len=maxp + max_new + 1,
        )
        try:
            rids = [self.submit(p, max_new=max_new,
                                quality=None if qualities is None else qualities[i])
                    for i, p in enumerate(prompts)]
            done = self.run_until_drained()
            return [done[r].tokens for r in rids]
        finally:
            self._session = saved

    def _generate_static(self, prompts, max_new: int, seed: int):
        """The one-static-batch path: every slot prefills and decodes in
        lockstep, and the whole batch drains before the call returns."""
        b = len(prompts)
        slots = self.cfg.batch_slots
        maxp = max(len(p) for p in prompts)
        cache_len = maxp + max_new + 1

        cache = init_params(
            jax.random.PRNGKey(0), self.model.cache_descs(slots, cache_len)
        )
        toks = np.zeros((slots, maxp), dtype=np.int32)
        lens = np.zeros((slots,), dtype=np.int32)
        for i, p in enumerate(prompts):
            toks[i, maxp - len(p):] = p  # left-pad
            lens[i] = len(p)
        # one jitted dispatch primes the cache for the whole prompt batch
        # (lens masks each slot's left padding out of the KV cache)...
        cache, logits = self._prefill(
            self.params, cache, jnp.asarray(toks), jnp.asarray(lens)
        )
        temp = self.cfg.temperature
        # ...and one jitted scan emits all max_new tokens; the np.asarray
        # below is the only host sync of the generation.
        if temp > 0:
            if self._sample_loop is None:
                self._sample_loop = jax.jit(make_sample_decode_loop(self.model))
            k_first, k_loop = jax.random.split(jax.random.PRNGKey(seed))
            first = jax.random.categorical(
                k_first, logits / temp, axis=-1
            ).astype(jnp.int32)[:, None]
            out_toks, _ = self._sample_loop(
                self.params, cache, first, jax.random.split(k_loop, max_new),
                jnp.float32(temp),
            )
        else:
            first = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            out_toks, _ = self._decode_loop(
                self.params, cache, first, jnp.arange(max_new)
            )
        out = np.asarray(out_toks)  # (max_new, slots)
        return [out[:, i].tolist() for i in range(b)]

"""Batched serving engine.

Loads a model from an exact or QSQ-wire checkpoint (the latter is the
paper's edge flow: the 3-bit + scalar artifact crosses the channel and is
decoded on arrival with shift/scale), then serves batched greedy decoding
with a slot-based KV cache (requests of different lengths share one step
loop — continuous-batching-lite).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.api import Model
from repro.models.base import init_params
from repro.quant import dequantize_pytree, unpack_pytree_wire
from repro.train.step import make_serve_step


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_slots: int = 8
    max_len: int = 256
    temperature: float = 0.0  # 0 => greedy


class ServeEngine:
    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.cfg = cfg
        self.params = params
        self.serve_step = jax.jit(make_serve_step(model))
        self._prefill = jax.jit(
            lambda p, b: model.forward(p, b)
        )

    # -- loading -----------------------------------------------------------
    @classmethod
    def from_wire(cls, model: Model, wire_tree, cfg: ServeConfig):
        """Decode a QSQ wire artifact (3-bit codes + scalars) into params.

        This is the paper's on-edge decoder: only shift/scale arithmetic,
        executed once at load; the decoded weights then serve inference.
        """
        qp = unpack_pytree_wire(wire_tree)
        params = dequantize_pytree(qp)
        return cls(model, params, cfg)

    # -- generation ----------------------------------------------------------
    def generate(self, prompts: Sequence[Sequence[int]], max_new: int = 32):
        """Greedy-decode a batch of token-id prompts.  Returns lists of ids."""
        b = len(prompts)
        slots = self.cfg.batch_slots
        if b > slots:
            raise ValueError(f"{b} prompts > {slots} slots")
        cfg = self.model.cfg
        maxp = max(len(p) for p in prompts)
        cache_len = maxp + max_new + 1

        cache = init_params(
            jax.random.PRNGKey(0), self.model.cache_descs(slots, cache_len)
        )
        # teacher-forced prefill through the decode path (simple + correct;
        # big-batch deployments lower a dedicated prefill step instead)
        toks = np.zeros((slots, maxp), dtype=np.int32)
        for i, p in enumerate(prompts):
            toks[i, maxp - len(p):] = p  # left-pad
        logits = None
        for t in range(maxp):
            logits, cache = self.model.decode(
                self.params, cache, {"tokens": jnp.asarray(toks[:, t : t + 1])}
            )
        out = [[] for _ in range(slots)]
        cur = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        for _ in range(max_new):
            for i in range(b):
                out[i].append(int(cur[i, 0]))
            cur, cache = self.serve_step(self.params, cache, {"tokens": cur})
        return [out[i] for i in range(b)]

"""Batched serving engine — serves directly from the 3-bit wire.

Loads a model from an exact or QSQ-wire checkpoint.  The wire path is the
paper's edge flow: the 3-bit + scalar artifact crosses the channel and is
served WITHOUT a full-tree dequantize — matmul weights stay packed
(:class:`~repro.quant.store.PackedWeight` bit-planes) end-to-end and are
decoded tile-by-tile inside the fused Pallas dequant-matmul, so serving
actually realizes the 3.2-4.6x weight-HBM cut the kernel was built for.
Only non-matmul leaves (embeddings, norms, attention output projections,
convs) are decoded once at load, per the QuantPolicy exclusions.

Generation is two jitted programs: a scanned prefill that primes the cache
for the whole prompt in one dispatch, and a multi-token greedy decode scan
that syncs with the host exactly once per generate() call.  Requests of
different lengths share one slot-based KV cache (continuous-batching-lite).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.models.base import init_params
from repro.train.step import (
    make_cache_prefill_step, make_decode_loop, make_serve_step,
)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_slots: int = 8
    max_len: int = 256
    temperature: float = 0.0  # 0 => greedy
    packed: bool = True  # from_wire: keep matmul weights in bit-plane form


class ServeEngine:
    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.cfg = cfg
        self.params = params
        self.n_packed_leaves = 0  # overwritten by from_wire
        self.serve_step = jax.jit(make_serve_step(model))
        self._prefill = jax.jit(make_cache_prefill_step(model))
        self._decode_loop = jax.jit(make_decode_loop(model))

    # -- loading -----------------------------------------------------------
    @classmethod
    def from_wire(cls, model: Model, wire_tree, cfg: ServeConfig):
        """Build an engine from a QSQ wire artifact (3-bit codes + scalars).

        With ``cfg.packed`` (default), kernel-eligible matmul weights are
        re-packed to bit-planes and SERVED in that form — no full-tree
        dequantize ever happens; the shift-and-scale decode (Table II) runs
        inside the matmul kernel at use time.  Leaves the kernel cannot
        consume (or wires grouped along a non-contraction axis) are decoded
        once here, which is also the complete behavior of ``packed=False``.
        """
        params, n_packed = model.serve_params(wire_tree, packed=cfg.packed)
        eng = cls(model, params, cfg)
        eng.n_packed_leaves = n_packed
        return eng

    # -- generation ----------------------------------------------------------
    def generate(self, prompts: Sequence[Sequence[int]], max_new: int = 32):
        """Greedy-decode a batch of token-id prompts.  Returns lists of ids."""
        b = len(prompts)
        slots = self.cfg.batch_slots
        if b > slots:
            raise ValueError(f"{b} prompts > {slots} slots")
        maxp = max(len(p) for p in prompts)
        cache_len = maxp + max_new + 1

        cache = init_params(
            jax.random.PRNGKey(0), self.model.cache_descs(slots, cache_len)
        )
        toks = np.zeros((slots, maxp), dtype=np.int32)
        for i, p in enumerate(prompts):
            toks[i, maxp - len(p):] = p  # left-pad
        # one jitted scan primes the cache for the whole prompt...
        cache, logits = self._prefill(self.params, cache, jnp.asarray(toks))
        first = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        # ...and one jitted scan emits all max_new tokens; the np.asarray
        # below is the only host sync of the generation.
        out_toks, _ = self._decode_loop(
            self.params, cache, first, jnp.arange(max_new)
        )
        out = np.asarray(out_toks)  # (max_new, slots)
        return [out[:, i].tolist() for i in range(b)]

"""Batched serving engine — serves directly from the 3-bit wire.

Engines are normally built through the quality-dial facade
(:func:`repro.api.compress` -> ``EdgeArtifact.engine(quality=...)``): the
wire path is the paper's edge flow — the 3-bit + scalar artifact crosses
the channel and is served WITHOUT a full-tree dequantize.  Matmul weights
stay packed (:class:`~repro.quant.store.PackedWeight` bit-planes) end to
end and are decoded tile-by-tile inside the fused Pallas dequant-matmul,
so serving actually realizes the 3.2-4.6x weight-HBM cut the kernel was
built for.  Only non-matmul leaves (embeddings, norms, attention output
projections, convs) are decoded once at load, per the QuantPolicy
exclusions.  ``set_quality`` re-dials an artifact-built engine to another
tier in place — LSB plane truncation on the already-loaded wire, never a
re-quantize.

Generation is two jitted programs: a ONE-DISPATCH prefill that primes the
cache for the whole left-padded prompt batch in a single causal-masked
forward — every packed weight streams once per prompt, not once per token
(recurrent/cross families fall back to a scanned per-token prefill) — and
a multi-token decode scan (greedy, or temperature-sampled when
``ServeConfig.temperature > 0``) that syncs with the host exactly once per
generate() call.  The decode steps route small-M packed matmuls through
the GEMV kernel picked by ``kernels/dispatch.py``.  Requests of different
lengths share one slot-based KV cache (continuous-batching-lite); each
slot's left padding is masked out of attention, so a dense-family
prompt's tokens are exactly invariant to its batch mates (MoE keeps the
weaker guarantee the scan prefill had: batch mates — padded or not —
share expert capacity and can shift routing under overflow).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model
from repro.models.base import init_params
from repro.train.step import (
    make_cache_prefill_step, make_decode_loop, make_sample_decode_loop,
    make_serve_step,
)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_slots: int = 8
    max_len: int = 256
    temperature: float = 0.0  # 0 => greedy; > 0 => categorical sampling
    packed: bool = True  # wire loads: keep matmul weights in bit-plane form


class ServeEngine:
    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.cfg = cfg
        self.params = params
        self.n_packed_leaves = 0  # overwritten by the artifact/wire loaders
        self.artifact = None      # set by EdgeArtifact.engine (quality dial)
        self.quality: str | None = None
        self.serve_step = jax.jit(make_serve_step(model))
        self._prefill = jax.jit(make_cache_prefill_step(model))
        self._decode_loop = jax.jit(make_decode_loop(model))
        self._sample_loop = None  # jitted lazily; most engines stay greedy

    # -- loading -----------------------------------------------------------
    @classmethod
    def from_wire(cls, model: Model, wire_tree, cfg: ServeConfig):
        """Deprecated shim over :class:`repro.quant.artifact.EdgeArtifact`.

        Equivalent to ``EdgeArtifact(wire, model.cfg).engine("hi",
        serve_cfg=cfg)``: full-quality serving with kernel-eligible matmul
        weights re-packed to bit-planes (``cfg.packed``, default) or a full
        dense decode at load (``packed=False``).  New code should call
        ``repro.api.compress(...)`` and dial quality on the artifact.
        """
        warnings.warn(
            "ServeEngine.from_wire is deprecated; use repro.api.compress() "
            "/ EdgeArtifact.engine(quality=...) instead",
            DeprecationWarning, stacklevel=2,
        )
        from repro.quant.artifact import EdgeArtifact

        art = EdgeArtifact(wire=wire_tree, arch_config=model.cfg)
        return art.engine(quality="hi", serve_cfg=cfg)

    # -- quality dial ------------------------------------------------------
    def set_quality(self, quality: str) -> "ServeEngine":
        """Re-resolve the param tree at another tier of this engine's
        artifact, in place — plane truncation on the loaded wire, no reload
        and no re-quantization.  The jitted programs take params as
        arguments, so the dial costs one retrace, not a rebuild."""
        if self.artifact is None:
            raise ValueError(
                "this engine was not built from an EdgeArtifact; construct "
                "it via repro.api.compress(...).engine(quality=...) to dial "
                "quality"
            )
        self.params, self.n_packed_leaves = self.artifact.serve_params(
            quality, packed=self.cfg.packed
        )
        self.quality = quality
        return self

    # -- generation ----------------------------------------------------------
    def generate(self, prompts: Sequence[Sequence[int]], max_new: int = 32,
                 seed: int = 0):
        """Decode a batch of token-id prompts.  Returns lists of ids.

        Greedy when ``cfg.temperature == 0``; otherwise samples from
        ``softmax(logits / temperature)`` with a PRNG derived from ``seed``
        (same seed + prompts => same tokens).
        """
        if len(prompts) == 0:
            return []
        if any(len(p) == 0 for p in prompts):
            raise ValueError("every prompt must contain at least one token")
        b = len(prompts)
        slots = self.cfg.batch_slots
        if b > slots:
            raise ValueError(
                f"{b} prompts exceed the engine's {slots} batch slots; "
                f"raise ServeConfig.batch_slots or split the batch"
            )
        maxp = max(len(p) for p in prompts)
        cache_len = maxp + max_new + 1

        cache = init_params(
            jax.random.PRNGKey(0), self.model.cache_descs(slots, cache_len)
        )
        toks = np.zeros((slots, maxp), dtype=np.int32)
        lens = np.zeros((slots,), dtype=np.int32)
        for i, p in enumerate(prompts):
            toks[i, maxp - len(p):] = p  # left-pad
            lens[i] = len(p)
        # one jitted dispatch primes the cache for the whole prompt batch
        # (lens masks each slot's left padding out of the KV cache)...
        cache, logits = self._prefill(
            self.params, cache, jnp.asarray(toks), jnp.asarray(lens)
        )
        temp = self.cfg.temperature
        # ...and one jitted scan emits all max_new tokens; the np.asarray
        # below is the only host sync of the generation.
        if temp > 0:
            if self._sample_loop is None:
                self._sample_loop = jax.jit(make_sample_decode_loop(self.model))
            k_first, k_loop = jax.random.split(jax.random.PRNGKey(seed))
            first = jax.random.categorical(
                k_first, logits / temp, axis=-1
            ).astype(jnp.int32)[:, None]
            out_toks, _ = self._sample_loop(
                self.params, cache, first, jax.random.split(k_loop, max_new),
                jnp.float32(temp),
            )
        else:
            first = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            out_toks, _ = self._decode_loop(
                self.params, cache, first, jnp.arange(max_new)
            )
        out = np.asarray(out_toks)  # (max_new, slots)
        return [out[:, i].tolist() for i in range(b)]

"""SLO admission control: degrade quality instead of latency.

The paper's tiers are ONE set of packed 3-bit weights with per-tier LSB
plane drops — so under overload the serving stack has a cheaper product
on the same shelf: admit the request at a lower tier and every one of
its dispatches streams fewer weight planes (PR 5's per-row plane masks
realize the tier inside the shared dispatch; PR 6's plane-demand floor
turns it into shorter HBM reads).  This module is the decision layer:
a pluggable :class:`AdmissionPolicy` consulted by
``ServeEngine.submit`` with a :class:`LoadView` snapshot, answering
ADMIT (possibly at a downgraded tier), SHED (even the cheapest tier
cannot meet the SLO — terminal ``FinishReason.SHED``) or REJECT
(structural refusal — terminal ``FinishReason.REJECTED``).

Everything here is host-side and jax-free.  Costs are denominated in
the engine's dispatch cost clock: one full-quality forward = 1.0, a
demand-shortened forward = its weight-read fraction
(``ServeEngine.tier_cost_table``) — the HBM-bandwidth time model the
plane-streaming kernels optimize.  :class:`QualityShed` is a greedy
knapsack over that table: outstanding work defines the occupied
capacity, and each arrival is admitted at the best (highest-quality)
tier whose added cost still fits the latency budget — shrinking the
item rather than dropping it, and shedding only when even the smallest
size misses.  The system self-regulates: every downgraded admission
adds less outstanding cost, so the estimated wait later arrivals see
grows slower, which is exactly Moons et al.'s system-level
energy/accuracy tradeoff applied to admission control.
"""
from __future__ import annotations

import dataclasses

ADMIT = "admit"
SHED = "shed"
REJECT = "reject"


@dataclasses.dataclass(frozen=True)
class SLOBudget:
    """The service-level objective admission decisions are made against.

    ``latency`` is the end-to-end budget per request — arrival to last
    token — in cost-clock units (full-quality dispatches).  ``max_queue``
    optionally REJECTS outright past a queue depth, independent of the
    latency estimate (a structural cap on buffered work)."""

    latency: float
    max_queue: int | None = None


@dataclasses.dataclass(frozen=True)
class LoadView:
    """What a policy sees at one submit: the stream's outstanding work.

    ``queued``/``live`` list (tier index, remaining dispatches) per
    request; ``tier_costs[t]`` is the engine's per-dispatch cost at tier
    ``t`` (indexed like ``tier_names``, best quality first)."""

    step: int
    now: float
    n_slots: int
    tier_names: tuple[str, ...]
    tier_costs: tuple[float, ...]
    queued: tuple[tuple[int, int], ...]
    live: tuple[tuple[int, int], ...]

    def outstanding_cost(self) -> float:
        """Cost-clock units of work already accepted and not yet served."""
        return sum(n * self.tier_costs[t]
                   for t, n in self.queued + self.live)

    def estimated_wait(self) -> float:
        """Optimistic clock time until a NEW arrival starts being served:
        outstanding cost spread across the slots.  Optimistic because the
        batch demand floor couples lanes (a single hi lane keeps the
        shared dispatch at hi cost); policies should treat it as a lower
        bound and budget accordingly."""
        return self.outstanding_cost() / max(self.n_slots, 1)


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    """``action`` is ADMIT/SHED/REJECT; ``tier`` the (possibly
    downgraded) tier index to serve at when admitting; ``detail`` a
    human-readable why, surfaced on the request's terminal status."""

    action: str
    tier: int | None = None
    detail: str = ""


class AdmissionPolicy:
    """Strategy hook consulted once per ``submit`` (never on the decode
    path — admission is pure host bookkeeping, zero retrace risk)."""

    def decide(self, tier: int, n_dispatches: int,
               view: LoadView) -> AdmissionDecision:
        raise NotImplementedError


class AdmitAll(AdmissionPolicy):
    """The pre-SLO discipline: FIFO, requested tier, unbounded wait —
    the overload baseline the bench replays against QualityShed."""

    def decide(self, tier: int, n_dispatches: int,
               view: LoadView) -> AdmissionDecision:
        return AdmissionDecision(ADMIT, tier=tier)


@dataclasses.dataclass
class QualityShed(AdmissionPolicy):
    """Greedy quality-scalable shedding against an :class:`SLOBudget`.

    For each arrival, walk the tier ladder from the requested tier down:
    the first tier whose estimated completion (current estimated wait +
    the request's own dispatches at that tier's cost) fits the latency
    budget wins.  If even the cheapest tier misses, SHED — the typed
    outcome the caller can retry later — rather than queue work that is
    already doomed to time out.  ``budget.max_queue`` REJECTs on queue
    depth before any estimating."""

    budget: SLOBudget

    def decide(self, tier: int, n_dispatches: int,
               view: LoadView) -> AdmissionDecision:
        if (self.budget.max_queue is not None
                and len(view.queued) >= self.budget.max_queue):
            return AdmissionDecision(
                REJECT,
                detail=(f"queue depth {len(view.queued)} at the policy cap "
                        f"{self.budget.max_queue}"),
            )
        wait = view.estimated_wait()
        for t in range(tier, len(view.tier_costs)):
            est = wait + n_dispatches * view.tier_costs[t]
            if est <= self.budget.latency:
                detail = ("" if t == tier else
                          f"downgraded {view.tier_names[tier]} -> "
                          f"{view.tier_names[t]}: est {est:.2f} fits "
                          f"budget {self.budget.latency:.2f}")
                return AdmissionDecision(ADMIT, tier=t, detail=detail)
        floor = len(view.tier_costs) - 1
        est = wait + n_dispatches * view.tier_costs[floor]
        return AdmissionDecision(
            SHED,
            detail=(f"even {view.tier_names[floor]} estimates {est:.2f} "
                    f"against budget {self.budget.latency:.2f}"),
        )

from repro.serve.admission import (
    AdmissionDecision,
    AdmissionPolicy,
    AdmitAll,
    LoadView,
    QualityShed,
    SLOBudget,
)
from repro.serve.engine import ServeConfig, ServeEngine, StepInfo
from repro.serve.scheduler import (
    FinishReason,
    QueueFullError,
    Request,
    RequestStatus,
    Scheduler,
    SlotState,
    SpecConfig,
    SubmitRejected,
)

__all__ = [
    "AdmissionDecision",
    "AdmissionPolicy",
    "AdmitAll",
    "FinishReason",
    "LoadView",
    "QualityShed",
    "QueueFullError",
    "Request",
    "RequestStatus",
    "Scheduler",
    "ServeConfig",
    "ServeEngine",
    "SLOBudget",
    "SlotState",
    "SpecConfig",
    "StepInfo",
    "SubmitRejected",
]

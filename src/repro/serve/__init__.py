from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.scheduler import Request, Scheduler, SlotState

__all__ = ["Request", "Scheduler", "ServeConfig", "ServeEngine", "SlotState"]

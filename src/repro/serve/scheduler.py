"""Request-level continuous batching: the admit/evict loop over slots.

The paper's 3-bit artifacts only pay off when the dialed-down hardware is
kept busy: a static batch ties every slot to the slowest request, so a
new prompt waits for the whole batch to drain before its first token.
This module is the host-side half of the fix — pure bookkeeping, no jax:

* :class:`Request` — one submitted prompt with its arrival/admission/
  finish step indices and the tokens emitted so far;
* :class:`Scheduler` — a FIFO admission queue plus a per-slot state
  machine ``FREE -> PREFILLING -> DECODING -> DONE (-> FREE)``.

The device half lives in :class:`~repro.serve.engine.ServeEngine`: each
``engine.step()`` first admits queued requests into FREE slots (one
single-slot prefill + cache lane insert per admission, both jitted once)
and then runs ONE fixed-width decode iteration over all lanes, with the
per-slot ``active`` mask making finished/empty slots dead lanes instead
of shape changes.  A request that reaches ``max_new`` goes DONE and is
evicted in the same step, freeing its slot for the next admission —
batch mates never flush.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
from typing import Iterator, Sequence


def plane_demand(live_tiers, default: int = 0) -> int:
    """Batch plane-demand floor for one decode tick.

    ``live_tiers`` are the quality-tier indices of the slots that will be
    live lanes in the dispatch (lower index = higher quality = more
    bit-planes kept).  The floor is their minimum: the batch must stream
    every plane its most-demanding live slot keeps, and nothing more — the
    OR of the live slots' plane masks collapses to the min tier index
    because each packed leaf turns it into a per-leaf drop via a suffix
    min over its tier-drop vector (``PackedWeight.demand_drop``), which
    never under-reads a live tier even when a leaf's drops are
    non-monotone.  The engine passes the result as a STATIC
    jit argument, so distinct demands retrace once each, bounded by the
    tier count rather than 2^planes.  With no live slots there is nothing
    to stream; ``default`` keeps the return a valid dispatch key."""
    tiers = [int(t) for t in live_tiers]
    return min(tiers) if tiers else int(default)


class SlotState(enum.Enum):
    FREE = "free"            # no request; a dead lane in the decode program
    PREFILLING = "prefilling"  # admission in flight: prompt -> cache lane
    DECODING = "decoding"    # live lane: one token per engine.step()
    DONE = "done"            # reached max_new; evicted before step() returns


@dataclasses.dataclass
class Request:
    """One prompt's life in the scheduler (all times are step indices).

    ``quality`` is the request's OWN tier name (per-request quality dial),
    resolved by the engine at submission time — None on engines that serve
    a single tier.  The scheduler treats it as opaque payload."""

    rid: int
    tokens: tuple[int, ...]  # prompt token ids
    max_new: int
    arrival: int
    quality: str | None = None
    admitted: int | None = None
    finished: int | None = None
    out: list[int] = dataclasses.field(default_factory=list)

    @property
    def waiting(self) -> int | None:
        """Steps spent queued before a slot opened (None until admitted)."""
        return None if self.admitted is None else self.admitted - self.arrival

    @property
    def latency(self) -> int | None:
        """Arrival -> last token, in steps (None until finished)."""
        return None if self.finished is None else self.finished - self.arrival


class Scheduler:
    """Admission queue + slot state machine (host-side, deterministic).

    The engine drives it: ``submit`` enqueues, ``admissible`` pairs queued
    requests with FREE slots (FIFO), ``activate``/``start_decoding``
    transition an admission, ``record`` appends a decoded token, and
    ``evict`` returns a DONE slot to FREE.  ``completed`` keeps every
    finished Request for latency accounting; ``poll`` hands each result
    out exactly once.
    """

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"need at least one slot, got {n_slots}")
        self.n_slots = n_slots
        self.states: list[SlotState] = [SlotState.FREE] * n_slots
        self.slot_req: list[Request | None] = [None] * n_slots
        self.queue: collections.deque[Request] = collections.deque()
        self.completed: dict[int, Request] = {}
        self._unclaimed: dict[int, Request] = {}
        self._next_rid = 0

    # -- admission ---------------------------------------------------------
    def submit(self, tokens: Sequence[int], max_new: int, arrival: int,
               quality: str | None = None) -> int:
        if len(tokens) == 0:
            raise ValueError("every prompt must contain at least one token")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid=rid, tokens=tuple(tokens),
                                  max_new=max_new, arrival=arrival,
                                  quality=quality))
        return rid

    def admissible(self) -> Iterator[tuple[int, Request]]:
        """Pair queued requests with FREE slots, FIFO, popping both."""
        for slot in range(self.n_slots):
            if not self.queue:
                return
            if self.states[slot] is SlotState.FREE:
                yield slot, self.queue.popleft()

    def activate(self, slot: int, req: Request, step: int) -> None:
        assert self.states[slot] is SlotState.FREE
        self.states[slot] = SlotState.PREFILLING
        self.slot_req[slot] = req
        req.admitted = step

    def start_decoding(self, slot: int) -> None:
        assert self.states[slot] is SlotState.PREFILLING
        self.states[slot] = SlotState.DECODING

    # -- decode / eviction -------------------------------------------------
    def decoding_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.states) if s is SlotState.DECODING]

    def record(self, slot: int, token: int, step: int) -> bool:
        """Append one emitted token; True when the request just finished."""
        req = self.slot_req[slot]
        req.out.append(int(token))
        if len(req.out) >= req.max_new:
            self.states[slot] = SlotState.DONE
            req.finished = step
            return True
        return False

    def evict(self, slot: int) -> Request:
        """Return a DONE slot to FREE; the Request moves to ``completed``."""
        assert self.states[slot] is SlotState.DONE
        req = self.slot_req[slot]
        self.states[slot] = SlotState.FREE
        self.slot_req[slot] = None
        self.completed[req.rid] = req
        self._unclaimed[req.rid] = req
        return req

    # -- results -----------------------------------------------------------
    def poll(self, rid: int | None = None):
        """Finished tokens, handed out once.  ``poll()`` pops everything
        finished since the last poll as {rid: tokens}; ``poll(rid)`` pops
        that request's tokens, or None if it hasn't finished YET.  A rid
        that was never issued, or whose result was already claimed (by a
        bare ``poll()`` / ``run_until_drained()`` or an earlier
        ``poll(rid)``), raises KeyError — so ``None`` always means "keep
        stepping", never a silently lost result."""
        if rid is not None:
            if rid in self._unclaimed:
                return list(self._unclaimed.pop(rid).out)
            if rid in self.completed:
                raise KeyError(
                    f"request {rid} already claimed (poll()/run_until_"
                    f"drained() hands each result out once); its tokens "
                    f"remain readable via completed[{rid}].out"
                )
            if not 0 <= rid < self._next_rid:
                raise KeyError(f"unknown request id {rid}")
            return None  # still queued / prefilling / decoding
        out = {r: list(q.out) for r, q in self._unclaimed.items()}
        self._unclaimed.clear()
        return out

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(
            s in (SlotState.PREFILLING, SlotState.DECODING) for s in self.states
        )

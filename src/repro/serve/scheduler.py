"""Request-level continuous batching: the admit/evict loop over slots.

The paper's 3-bit artifacts only pay off when the dialed-down hardware is
kept busy: a static batch ties every slot to the slowest request, so a
new prompt waits for the whole batch to drain before its first token.
This module is the host-side half of the fix — pure bookkeeping, no jax:

* :class:`Request` — one submitted prompt with its arrival/admission/
  finish step indices, cost-clock timestamps, deadline, and the tokens
  emitted so far;
* :class:`Scheduler` — a BOUNDED admission queue plus a per-slot state
  machine ``FREE -> PREFILLING -> DECODING -> DONE (-> FREE)``.

The device half lives in :class:`~repro.serve.engine.ServeEngine`: each
``engine.step()`` first admits queued requests into FREE slots (one
single-slot prefill + cache lane insert per admission, both jitted once)
and then runs ONE fixed-width decode iteration over all lanes, with the
per-slot ``active`` mask making finished/empty slots dead lanes instead
of shape changes.  A request that reaches ``max_new`` goes DONE and is
evicted in the same step, freeing its slot for the next admission —
batch mates never flush.

Overload-graceful serving adds TYPED terminations: every request ends
with a :class:`FinishReason` (``DONE`` / ``TIMED_OUT`` / ``CANCELLED`` /
``SHED`` / ``REJECTED``) and :meth:`Scheduler.poll` hands back a
structured :class:`RequestStatus` instead of an ambiguous ``None``.
Deadline expiry and caller cancellation EVICT mid-decode — an
active-mask flip on the engine side, never a retrace — keeping any
tokens already emitted as a partial result.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
from typing import Iterator, Sequence


def plane_demand(live_tiers, default: int = 0) -> int:
    """Batch plane-demand floor for one decode tick.

    ``live_tiers`` are the quality-tier indices of the slots that will be
    live lanes in the dispatch (lower index = higher quality = more
    bit-planes kept).  The floor is their minimum: the batch must stream
    every plane its most-demanding live slot keeps, and nothing more — the
    OR of the live slots' plane masks collapses to the min tier index
    because each packed leaf turns it into a per-leaf drop via a suffix
    min over its tier-drop vector (``PackedWeight.demand_drop``), which
    never under-reads a live tier even when a leaf's drops are
    non-monotone.  The engine passes the result as a STATIC
    jit argument, so distinct demands retrace once each, bounded by the
    tier count rather than 2^planes.  With no live slots there is nothing
    to stream; ``default`` keeps the return a valid dispatch key."""
    tiers = [int(t) for t in live_tiers]
    return min(tiers) if tiers else int(default)


class SlotState(enum.Enum):
    FREE = "free"            # no request; a dead lane in the decode program
    PREFILLING = "prefilling"  # admission in flight: prompt -> cache lane
    DECODING = "decoding"    # live lane: one token per engine.step()
    DONE = "done"            # reached max_new; evicted before step() returns


class FinishReason(enum.Enum):
    """Why a request terminated — every request ends with exactly one.

    ``DONE`` is the only success; the rest are the overload/robustness
    outcomes: ``TIMED_OUT`` (deadline passed, queued or mid-decode, any
    tokens already emitted are kept as a partial result), ``CANCELLED``
    (caller-initiated :meth:`Scheduler.cancel`, likewise partial),
    ``SHED`` (the admission policy found that even the lowest quality
    tier cannot meet the SLO budget) and ``REJECTED`` (a structural
    refusal — bounded queue full, or an admission-policy queue cap)."""

    DONE = "done"
    TIMED_OUT = "timed_out"
    CANCELLED = "cancelled"
    SHED = "shed"
    REJECTED = "rejected"


class SubmitRejected(ValueError):
    """Typed submit-time rejection: the request could NEVER be served by
    this stream (oversized prompt, cache overflow, invalid deadline) —
    raised instead of queueing work that would hang the drain loop."""


class QueueFullError(SubmitRejected):
    """The scheduler's bounded queue is at ``max_queue``."""


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Per-request self-speculative decoding knobs.

    ``draft_tier`` names the quality tier the engine drafts at — a plane
    mask over the SAME packed weights, so the draft model is free (no
    second parameter tree, no extra HBM residency); it must sit strictly
    BELOW the request's serving tier on the ladder or there is nothing to
    save.  ``k`` is the draft window: tokens proposed per round before
    one batched verify dispatch at the serving tier accepts the longest
    agreeing prefix.  Outputs are token-identical to plain decode at the
    serving tier either way — speculation only changes which dispatches
    produced them."""

    draft_tier: str
    k: int = 4


@dataclasses.dataclass(frozen=True)
class RequestStatus:
    """One poll's view of a request — never ``None``, never ambiguous.

    ``state`` is ``queued`` / ``prefilling`` / ``decoding`` / ``done``;
    ``finish_reason`` is set exactly when ``state == "done"``.
    ``tokens`` carries the emitted ids once terminal (a PARTIAL list for
    ``TIMED_OUT`` / ``CANCELLED`` evictions, empty for ``SHED`` /
    ``REJECTED``) and ``None`` while the request is still in flight;
    ``n_tokens`` tracks live progress either way.  Step-index times
    (``arrival``/``admitted``/``finished``) count engine iterations; the
    ``*_t`` twins are on the engine's weight-stream cost clock (a
    full-quality dispatch costs 1.0, a demand-shortened one its
    read fraction), which is also the clock deadlines are enforced on."""

    rid: int
    state: str
    finish_reason: FinishReason | None
    tokens: list[int] | None
    n_tokens: int
    quality: str | None
    requested: str | None
    arrival: int
    admitted: int | None
    finished: int | None
    arrival_t: float
    admitted_t: float | None
    finished_t: float | None
    deadline: float | None
    detail: str = ""
    drafted: int = 0   # draft-tier tokens proposed for this request
    accepted: int = 0  # of those, accepted by a verify dispatch

    @property
    def done(self) -> bool:
        return self.finish_reason is not None

    @property
    def ok(self) -> bool:
        return self.finish_reason is FinishReason.DONE

    @property
    def waiting(self) -> int | None:
        return None if self.admitted is None else self.admitted - self.arrival

    @property
    def latency(self) -> int | None:
        return None if self.finished is None else self.finished - self.arrival

    @property
    def latency_t(self) -> float | None:
        """Arrival -> termination on the cost clock (None until then)."""
        if self.finished_t is None:
            return None
        return self.finished_t - self.arrival_t


@dataclasses.dataclass
class Request:
    """One prompt's life in the scheduler.

    ``arrival``/``admitted``/``finished`` are step indices;
    ``arrival_t``/``admitted_t``/``finished_t`` are the same moments on
    the engine's cost clock.  ``deadline`` is an ABSOLUTE cost-clock
    time: once the clock reaches it the request is timed out — popped
    from the queue, or evicted mid-decode with its partial output.
    ``quality`` is the tier the request is actually served at (the
    admission policy may have downgraded it); ``requested`` preserves
    the caller's ask.  The scheduler treats both as opaque payload."""

    rid: int
    tokens: tuple[int, ...]  # prompt token ids
    max_new: int
    arrival: int
    quality: str | None = None
    requested: str | None = None
    deadline: float | None = None
    admitted: int | None = None
    finished: int | None = None
    arrival_t: float = 0.0
    admitted_t: float | None = None
    finished_t: float | None = None
    finish_reason: FinishReason | None = None
    detail: str = ""
    speculate: SpecConfig | None = None
    drafted: int = 0
    accepted: int = 0
    out: list[int] = dataclasses.field(default_factory=list)

    @property
    def waiting(self) -> int | None:
        """Steps spent queued before a slot opened (None until admitted)."""
        return None if self.admitted is None else self.admitted - self.arrival

    @property
    def latency(self) -> int | None:
        """Arrival -> last token, in steps (None until finished)."""
        return None if self.finished is None else self.finished - self.arrival

    def status(self, state: str) -> RequestStatus:
        return RequestStatus(
            rid=self.rid, state=state, finish_reason=self.finish_reason,
            tokens=list(self.out) if self.finish_reason is not None else None,
            n_tokens=len(self.out), quality=self.quality,
            requested=self.requested, arrival=self.arrival,
            admitted=self.admitted, finished=self.finished,
            arrival_t=self.arrival_t, admitted_t=self.admitted_t,
            finished_t=self.finished_t, deadline=self.deadline,
            detail=self.detail, drafted=self.drafted,
            accepted=self.accepted,
        )


class Scheduler:
    """Admission queue + slot state machine (host-side, deterministic).

    The engine drives it: ``submit`` enqueues, ``admissible`` pairs queued
    requests with FREE slots (FIFO), ``activate``/``start_decoding``
    transition an admission, ``record`` appends a decoded token,
    ``evict`` returns a DONE slot to FREE, and ``release``/``cancel``/
    ``expire_queued`` terminate early with a typed reason.  ``completed``
    keeps every finished Request for latency accounting; a bare ``poll``
    hands each newly-terminal status out exactly once, while ``poll(rid)``
    is an idempotent structured-status read.
    """

    def __init__(self, n_slots: int, max_queue: int | None = None):
        if n_slots < 1:
            raise ValueError(f"need at least one slot, got {n_slots}")
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.n_slots = n_slots
        self.max_queue = max_queue
        self.states: list[SlotState] = [SlotState.FREE] * n_slots
        self.slot_req: list[Request | None] = [None] * n_slots
        self.queue: collections.deque[Request] = collections.deque()
        self.completed: dict[int, Request] = {}
        self._unclaimed: dict[int, Request] = {}
        self._next_rid = 0

    # -- admission ---------------------------------------------------------
    @property
    def queue_full(self) -> bool:
        return self.max_queue is not None and len(self.queue) >= self.max_queue

    def _new_request(self, tokens: Sequence[int], max_new: int, arrival: int,
                     quality, requested, deadline, arrival_t) -> Request:
        if len(tokens) == 0:
            raise SubmitRejected("every prompt must contain at least one token")
        if max_new < 1:
            raise SubmitRejected(f"max_new must be >= 1, got {max_new}")
        rid = self._next_rid
        self._next_rid += 1
        return Request(
            rid=rid, tokens=tuple(tokens), max_new=max_new, arrival=arrival,
            quality=quality, requested=requested, deadline=deadline,
            arrival_t=float(arrival) if arrival_t is None else float(arrival_t),
        )

    def submit(self, tokens: Sequence[int], max_new: int, arrival: int,
               quality: str | None = None, requested: str | None = None,
               deadline: float | None = None,
               arrival_t: float | None = None,
               speculate: SpecConfig | None = None) -> int:
        if self.queue_full:
            raise QueueFullError(
                f"admission queue is at its max_queue={self.max_queue} bound"
            )
        req = self._new_request(tokens, max_new, arrival, quality,
                                requested or quality, deadline, arrival_t)
        req.speculate = speculate
        self.queue.append(req)
        return req.rid

    def finish_unadmitted(self, tokens: Sequence[int], max_new: int,
                          arrival: int, reason: FinishReason,
                          quality: str | None = None,
                          requested: str | None = None,
                          arrival_t: float | None = None,
                          detail: str = "") -> int:
        """Issue a rid that is TERMINAL on arrival (``SHED``/``REJECTED``):
        the request never queues, never holds a slot, and surfaces through
        ``poll`` exactly like a served one — so overload outcomes are
        counted, not raised."""
        req = self._new_request(tokens, max_new, arrival, quality,
                                requested or quality, None, arrival_t)
        req.detail = detail
        self._finish(req, arrival, req.arrival_t, reason)
        return req.rid

    def admissible(self) -> Iterator[tuple[int, Request]]:
        """Pair queued requests with FREE slots, FIFO, popping both."""
        for slot in range(self.n_slots):
            if not self.queue:
                return
            if self.states[slot] is SlotState.FREE:
                yield slot, self.queue.popleft()

    def activate(self, slot: int, req: Request, step: int,
                 now: float | None = None) -> None:
        assert self.states[slot] is SlotState.FREE
        self.states[slot] = SlotState.PREFILLING
        self.slot_req[slot] = req
        req.admitted = step
        req.admitted_t = float(step) if now is None else float(now)

    def start_decoding(self, slot: int) -> None:
        assert self.states[slot] is SlotState.PREFILLING
        self.states[slot] = SlotState.DECODING

    # -- decode / eviction -------------------------------------------------
    def decoding_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.states) if s is SlotState.DECODING]

    def record(self, slot: int, token: int, step: int,
               now: float | None = None) -> bool:
        """Append one emitted token; True when the request just finished."""
        req = self.slot_req[slot]
        req.out.append(int(token))
        if len(req.out) >= req.max_new:
            self.states[slot] = SlotState.DONE
            req.finished = step
            req.finished_t = float(step) if now is None else float(now)
            req.finish_reason = FinishReason.DONE
            return True
        return False

    def _finish(self, req: Request, step: int, now: float,
                reason: FinishReason) -> None:
        if req.finish_reason is None or reason is not FinishReason.DONE:
            req.finish_reason = req.finish_reason or reason
        if req.finished is None:
            req.finished = step
            req.finished_t = float(now)
        self.completed[req.rid] = req
        self._unclaimed[req.rid] = req

    def evict(self, slot: int) -> Request:
        """Return a DONE slot to FREE; the Request moves to ``completed``."""
        assert self.states[slot] is SlotState.DONE
        req = self.slot_req[slot]
        self.states[slot] = SlotState.FREE
        self.slot_req[slot] = None
        self._finish(req, req.finished, req.finished_t, FinishReason.DONE)
        return req

    def release(self, slot: int, step: int, now: float,
                reason: FinishReason) -> Request:
        """Evict a live (DECODING) slot EARLY with a typed reason — the
        deadline/cancellation path.  The engine mirrors this with an
        active-mask flip (a data change, never a retrace); tokens already
        emitted stay on the Request as a partial result."""
        assert self.states[slot] in (SlotState.DECODING, SlotState.DONE)
        req = self.slot_req[slot]
        self.states[slot] = SlotState.FREE
        self.slot_req[slot] = None
        self._finish(req, step, now, reason)
        return req

    # -- deadlines / cancellation ------------------------------------------
    def expire_queued(self, step: int, now: float) -> list[Request]:
        """Pop every queued request whose deadline the cost clock has
        passed; each terminates TIMED_OUT without ever taking a slot."""
        expired = [r for r in self.queue
                   if r.deadline is not None and now >= r.deadline]
        if expired:
            dead = {r.rid for r in expired}
            self.queue = collections.deque(
                r for r in self.queue if r.rid not in dead)
            for r in expired:
                self._finish(r, step, now, FinishReason.TIMED_OUT)
        return expired

    def expired_decoding(self, now: float) -> list[int]:
        """Slots whose live request is past its deadline (evict next)."""
        return [i for i in self.decoding_slots()
                if self.slot_req[i].deadline is not None
                and now >= self.slot_req[i].deadline]

    def cancel(self, rid: int, step: int,
               now: float) -> tuple[Request | None, int | None]:
        """Caller-initiated abort -> (request, freed slot | None).

        Queued requests are removed outright; a live one is released
        mid-decode (the engine must flip its active lane off).  Already-
        terminal rids return (None, None) — cancellation is idempotent.
        Unknown rids raise KeyError."""
        for r in self.queue:
            if r.rid == rid:
                self.queue.remove(r)
                self._finish(r, step, now, FinishReason.CANCELLED)
                return r, None
        for slot, r in enumerate(self.slot_req):
            if r is not None and r.rid == rid:
                return self.release(slot, step, now,
                                    FinishReason.CANCELLED), slot
        if rid in self.completed:
            return None, None
        if not 0 <= rid < self._next_rid:
            raise KeyError(f"unknown request id {rid}")
        return None, None

    # -- results -----------------------------------------------------------
    def _state_of(self, req: Request) -> str:
        if req.finish_reason is not None:
            return "done"
        for slot, r in enumerate(self.slot_req):
            if r is req:
                return self.states[slot].value
        return "queued"

    def status(self, rid: int) -> RequestStatus:
        """Structured, idempotent view of one request (any known rid)."""
        req = self.completed.get(rid)
        if req is None:
            for r in self.slot_req:
                if r is not None and r.rid == rid:
                    req = r
                    break
        if req is None:
            for r in self.queue:
                if r.rid == rid:
                    req = r
                    break
        if req is None:
            raise KeyError(f"unknown request id {rid}")
        return req.status(self._state_of(req))

    def poll(self, rid: int | None = None):
        """Structured request status.

        ``poll(rid)`` returns that request's :class:`RequestStatus` — an
        idempotent read for ANY issued rid, whatever its state (``.done``
        / ``.tokens`` say whether and how it terminated; a non-terminal
        status means "keep stepping").  ``poll()`` pops every request
        that TERMINATED since the last bare poll as {rid: status} —
        hand-out-once, so a drain loop sees each outcome exactly once.
        Unknown rids raise KeyError."""
        if rid is not None:
            return self.status(rid)
        out = {r: q.status("done") for r, q in self._unclaimed.items()}
        self._unclaimed.clear()
        return out

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(
            s in (SlotState.PREFILLING, SlotState.DECODING) for s in self.states
        )

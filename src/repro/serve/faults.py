"""Deterministic fault injection for overload-graceful serving.

The paper's deployment story is hostile by construction: a 3-bit
artifact shipped over a lossy channel to an edge device that is
bandwidth-starved and bursty.  This module makes those conditions
reproducible — every injector is seeded and host-side, so the robustness
tests and the ``bench_serve`` overload sweep replay EXACTLY the same
degradation every run:

* **wire damage** — :func:`corrupt_plane_npz` flips bits inside one
  bit-plane of a saved artifact's packed codes (checksum verification at
  ``EdgeArtifact.load`` must cap the tier ceiling, or hard-error on the
  sign/MSB plane); :func:`truncate_planes_npz` zeroes trailing LSB
  planes of every leaf — the partial plane-major download, which under
  MSB-first streaming is *literally* a lower quality tier;
* **overload** — :func:`poisson_trace` / :func:`overload_trace` /
  :func:`burst_trace` build arrival traces in cost-clock units for
  :func:`replay`;
* **stragglers** — :func:`slow_ticks` injects periodic stalls through
  ``ServeEngine.advance_clock`` (deadlines keep aging while the engine
  loses a tick);
* **bad input** — :func:`oversized_prompt` builds a prompt the stream
  can never serve (must die as a typed ``SubmitRejected``, not a hang).

:func:`replay` is the harness: it drives one engine through an arrival
trace on the engine's own cost clock (idle gaps advance the clock, busy
periods let dispatch costs advance it) and returns a
:class:`ReplayReport` with the overload scorecard — p50/p90 latency,
shed/timeout/reject rates, realized quality mix, peak queue depth.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path

import numpy as np

__all__ = [
    "ReplayReport",
    "burst_trace",
    "corrupt_plane_npz",
    "overload_trace",
    "oversized_prompt",
    "poisson_trace",
    "replay",
    "slow_ticks",
    "truncate_planes_npz",
]


# --------------------------------------------------------------------------
# Wire damage (operates on saved EdgeArtifact npz files)
# --------------------------------------------------------------------------
def _packed_keys(files, leaf: str | None) -> list[str]:
    keys = sorted(k for k in files if k.endswith("['packed']")
                  and (leaf is None or leaf in k))
    if not keys:
        raise KeyError(
            f"no packed wire leaf matching {leaf!r} in the artifact")
    return keys


def _load_flat(path) -> dict:
    with np.load(Path(path), allow_pickle=False) as data:
        return {k: data[k] for k in data.files}


def _leaf_numel(flat: dict, packed_key: str) -> int:
    """Element count of the codes a packed leaf holds, from its sibling
    ``shape`` entry — stored either whole (``...['shape']``) or flattened
    per-dimension (``...['shape'][0]``, ``...['shape'][1]``, ...)."""
    stem = packed_key[: -len("['packed']")] + "['shape']"
    if stem in flat:
        return int(np.prod(np.asarray(flat[stem]).reshape(-1)))
    dims = [int(flat[k]) for k in sorted(flat) if k.startswith(stem + "[")]
    if not dims:
        raise KeyError(f"no shape entry for packed leaf {packed_key!r}")
    return int(np.prod(dims))


def _save_flat(flat: dict, path) -> Path:
    from repro.quant.artifact import atomic_savez

    return atomic_savez(flat, Path(path))


def corrupt_plane_npz(path, plane: int, leaf: str | None = None,
                      n_flips: int = 4, seed: int = 0,
                      out=None) -> Path:
    """Flip ``n_flips`` bits inside ONE bit-plane of one packed wire leaf.

    ``plane`` indexes MSB-first like the artifact's per-plane checksums:
    0 is the sign/MSB plane (corruption there is unrecoverable — load
    must raise), 2 is the trailing LSB plane (recoverable — load caps
    the tier ceiling).  ``leaf`` picks the first packed leaf whose npz
    key contains the substring (None: the first leaf).  Deterministic in
    ``seed``; writes to ``out`` (default: in place) and returns the path.
    """
    from repro.core import codec

    if not 0 <= plane < 3:
        raise ValueError(f"plane must be 0 (MSB) .. 2 (LSB), got {plane}")
    flat = _load_flat(path)
    key = _packed_keys(flat, leaf)[0]
    n = _leaf_numel(flat, key)
    codes = np.array(codec.unpack_dense(flat[key], n))  # writable copy
    rng = np.random.default_rng(seed)
    idx = rng.choice(n, size=min(int(n_flips), n), replace=False)
    codes[idx] ^= np.uint8(1 << (2 - plane))  # MSB-first index -> bit pos
    flat[key] = np.asarray(codec.pack_dense(codes, bits=3))
    return _save_flat(flat, out if out is not None else path)


def truncate_planes_npz(path, drop: int = 1, leaves=None, out=None) -> Path:
    """Zero the trailing ``drop`` LSB plane(s) of packed wire leaves —
    the artifact a receiver holds after a partial MSB-first plane-major
    download (missing planes read as zero bits).  ``leaves`` restricts
    the truncation to the named '/'-joined paths (a tier's ``drop_map``
    keys: under demand-driven streaming the tier ladder IS the download
    deferral schedule — only tier-deferrable planes arrive last); None
    truncates every leaf, which only a ladder truncating everything can
    absorb.  The result must load as a tier-capped artifact
    bit-identical to a checksum-repaired corrupted one."""
    from repro.core import codec
    from repro.quant.store import plane_mask_for_drop

    flat = _load_flat(path)
    mask = np.uint8(plane_mask_for_drop(drop))
    wanted = None if leaves is None else {
        "".join(f"['{seg}']" for seg in p.split("/")) + "['packed']"
        for p in leaves
    }
    for key in _packed_keys(flat, None):
        if wanted is not None and key not in wanted:
            continue
        n = _leaf_numel(flat, key)
        codes = np.asarray(codec.unpack_dense(flat[key], n)) & mask
        flat[key] = np.asarray(codec.pack_dense(codes, bits=3))
    return _save_flat(flat, out if out is not None else path)


# --------------------------------------------------------------------------
# Arrival traces / stragglers / bad input
# --------------------------------------------------------------------------
def poisson_trace(n: int, mean_gap: float, seed: int = 0) -> list[float]:
    """``n`` Poisson-process arrival times (cost-clock units): exponential
    inter-arrival gaps with the given mean, deterministic in ``seed``."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(mean_gap, size=n)).tolist()


def overload_trace(arrivals, factor: float) -> list[float]:
    """Compress a trace in time by ``factor`` — the same requests arriving
    ``factor``x faster (factor 1.0 is the trace unchanged)."""
    return [float(a) / float(factor) for a in arrivals]


def burst_trace(n: int, at: float = 0.0) -> list[float]:
    """``n`` simultaneous arrivals — the worst-case thundering herd."""
    return [float(at)] * n


def slow_ticks(every: int, stall: float):
    """Periodic straggler injector for :func:`replay`: every ``every``-th
    engine tick loses ``stall`` extra cost-clock units (host pause, GC,
    preemption) — deadlines keep aging through the stall."""
    if every < 1:
        raise ValueError(f"every must be >= 1, got {every}")

    def extra(tick: int) -> float:
        return float(stall) if (tick + 1) % every == 0 else 0.0

    return extra


def oversized_prompt(engine) -> list[int]:
    """A prompt one token wider than the engine's fixed prefill window —
    must be refused at submit with a typed SubmitRejected, never queued."""
    return [1] * (engine.cfg.max_prompt + 1)


# --------------------------------------------------------------------------
# Replay harness
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ReplayReport:
    """The overload scorecard of one :func:`replay` run.

    ``statuses`` maps rid -> terminal RequestStatus; ``arrivals`` maps
    rid -> the TRACE arrival time (latencies are measured from it, so
    queueing delay during busy periods is charged to the request).
    Latency pools include every request that was actually taken on
    (DONE, and TIMED_OUT/CANCELLED at their eviction time); SHED and
    REJECTED requests never consumed service and are scored by their
    rates instead."""

    statuses: dict
    arrivals: dict
    ticks: int
    makespan: float
    max_queue_depth: int

    def latencies(self) -> list[float]:
        out = []
        for rid, st in self.statuses.items():
            if st.finish_reason is not None and st.finish_reason.value in (
                    "done", "timed_out", "cancelled"):
                out.append(st.finished_t - self.arrivals[rid])
        return out

    def rate(self, reason: str) -> float:
        n = sum(1 for st in self.statuses.values()
                if st.finish_reason is not None
                and st.finish_reason.value == reason)
        return n / max(len(self.statuses), 1)

    def quality_mix(self) -> dict[str, int]:
        """Realized tiers of requests that were actually admitted."""
        mix: dict[str, int] = {}
        for st in self.statuses.values():
            if st.admitted is not None:
                mix[st.quality or "default"] = mix.get(st.quality or "default", 0) + 1
        return mix

    def summary(self) -> dict:
        lat = self.latencies()
        return {
            "n": len(self.statuses),
            "p50_latency": round(float(np.percentile(lat, 50)), 3) if lat else 0.0,
            "p90_latency": round(float(np.percentile(lat, 90)), 3) if lat else 0.0,
            "mean_latency": round(float(np.mean(lat)), 3) if lat else 0.0,
            "done_rate": round(self.rate("done"), 3),
            "timeout_rate": round(self.rate("timed_out"), 3),
            "shed_rate": round(self.rate("shed"), 3),
            "reject_rate": round(self.rate("rejected"), 3),
            "quality_mix": self.quality_mix(),
            "max_queue_depth": self.max_queue_depth,
            "ticks": self.ticks,
            "makespan": round(float(self.makespan), 3),
        }


def replay(engine, prompts, arrivals, max_new: int = 8, qualities=None,
           deadline: float | None = None, slow=None,
           max_ticks: int = 50_000) -> ReplayReport:
    """Drive ``engine`` through an arrival trace on its own cost clock.

    Each prompt is submitted the moment the engine clock reaches its
    arrival time; idle gaps are skipped by ``advance_clock`` (deadlines
    still age), busy periods advance the clock through dispatch costs.
    ``deadline`` is the per-request relative budget; ``slow`` an optional
    :func:`slow_ticks`-style injector.  Deterministic: same engine +
    trace => same report."""
    if qualities is None:
        qualities = [None] * len(prompts)
    elif isinstance(qualities, str):
        qualities = [qualities] * len(prompts)
    order = np.argsort(np.asarray(arrivals), kind="stable")
    rids: dict[int, int] = {}
    arr_t: dict[int, float] = {}
    i = 0
    ticks = 0
    max_depth = 0
    while True:
        while i < len(order) and arrivals[order[i]] <= engine.now + 1e-9:
            j = int(order[i])
            rid = engine.submit(prompts[j], max_new=max_new,
                                quality=qualities[j], deadline=deadline)
            rids[rid] = j
            arr_t[rid] = float(arrivals[j])
            i += 1
        max_depth = max(max_depth, engine.queue_depth)
        if not engine.has_work:
            if i >= len(order):
                break
            # idle until the next arrival: jump the clock, don't spin
            engine.advance_clock(float(arrivals[order[i]]) - engine.now)
            continue
        engine.step()
        if slow is not None:
            extra = slow(ticks)
            if extra:
                engine.advance_clock(extra)
        ticks += 1
        if ticks > max_ticks:
            raise RuntimeError(
                f"replay watchdog: {ticks} ticks without draining "
                f"({engine.queue_depth} queued)")
    return ReplayReport(
        statuses={rid: engine.poll(rid) for rid in rids},
        arrivals=arr_t, ticks=ticks, makespan=engine.now,
        max_queue_depth=max_depth,
    )

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Run as:  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek_7b --shape train_4k
         PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Produces experiments/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis, cost_analysis, per-collective byte counts and the three
roofline terms (benchmarks/roofline.py aggregates these files).
"""
# The VERY FIRST lines, before ANY other import: jax locks the device count
# on first init, and the dry-run needs 512 host placeholder devices.
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, cell_is_supported, get_arch  # noqa: E402
from repro.core.energy import roofline_terms  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_axis_sizes, sharding_rules  # noqa: E402
from repro.models.api import Model  # noqa: E402
from repro.models.base import abstract_params, partition_specs  # noqa: E402
from repro.train.state import train_state_descs  # noqa: E402
from repro.train.step import make_prefill_step, make_serve_step, make_train_step  # noqa: E402

RESULTS_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+|pred)\[([0-9,]*)\]")


def _shape_bytes(m: re.Match) -> int:
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Per-collective-kind result bytes summed over the (per-device) module."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        rhs = rhs.strip()
        kind = None
        for k in _COLLECTIVES:
            # match the op name at the start of the rhs (after the shape),
            # e.g.  bf16[2048,512]{1,0} all-gather(...)
            if re.search(rf"\b{k}(-start|-done)?\(", rhs):
                kind = k
                break
        if kind is None or f"{kind}-done(" in rhs:
            continue  # -done carries no new bytes; counted at -start
        bytes_ = sum(_shape_bytes(m) for m in _SHAPE_RE.finditer(rhs.split(kind)[0]))
        out[kind] += bytes_
        counts[kind] += 1
    out_counts = {f"n_{k}": v for k, v in counts.items()}
    return {**out, **out_counts, "total": sum(out[k] for k in _COLLECTIVES)}


def model_flops_estimate(model: Model, shape) -> float:
    """6 * N_active * D (train) / 2 * N_active * tokens (decode/prefill)."""
    cfg = model.cfg
    descs = model.param_descs()
    n_total = 0
    n_active = 0.0
    for _path, d in jax.tree_util.tree_leaves_with_path(
        descs, is_leaf=lambda x: hasattr(x, "axes")
    ):
        numel = int(np.prod(d.shape))
        n_total += numel
        if "experts" in d.axes and cfg.moe is not None:
            n_active += numel * cfg.moe.top_k / cfg.moe.n_experts
        else:
            n_active += numel
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens, n_total, n_active


def _named(mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree (jit needs Shardings when the
    mesh context is not yet entered)."""
    from jax.sharding import NamedSharding, PartitionSpec

    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )


def probe_granularity(cfg) -> int:
    """Smallest layer count that preserves the arch's block structure."""
    if cfg.family == "hybrid":
        return cfg.hybrid.period
    if cfg.family == "vlm":
        return cfg.cross_every
    return 1


def probe_config(cfg, mult: int):
    """Reduced-depth copy of cfg (same widths) for unrolled cost probes."""
    import dataclasses as _dc

    g = probe_granularity(cfg)
    changes = {"n_layers": g * mult}
    if cfg.family == "encdec":
        changes["enc_layers"] = mult
    return _dc.replace(cfg, **changes)


def build_cell(arch_id: str, shape_name: str, mesh, fsdp: bool = True,
               rules_override=None, cfg_override=None, packed: bool = False):
    """Returns (jitted_fn, example_args_abstract) for a cell.

    packed=True serves decode/prefill shapes with QSQ bit-plane weights
    (quant/packed.py) — the paper's decode-on-use, measured in §Perf."""
    cfg = cfg_override if cfg_override is not None else get_arch(arch_id)
    model = Model(cfg)
    shape = SHAPES[shape_name]
    rules = dict(sharding_rules(mesh, fsdp=fsdp))
    if rules_override:
        rules.update(rules_override)
    sizes = mesh_axis_sizes(mesh)

    batch_descs = model.input_descs(shape)
    batch_abs = abstract_params(batch_descs)
    batch_spec = _named(mesh, partition_specs(batch_descs, rules, sizes))

    if shape.kind == "train":
        sd = train_state_descs(model)
        state_abs = abstract_params(sd)
        state_spec = _named(mesh, partition_specs(sd, rules, sizes))
        step = make_train_step(model)
        jitted = jax.jit(
            step,
            in_shardings=(state_spec, batch_spec),
            out_shardings=(state_spec, None),
            donate_argnums=(0,),
        )
        args = (state_abs, batch_abs)
    elif shape.kind == "prefill":
        pd = model.param_descs()
        if packed:
            from repro.quant.packed import packed_param_descs

            pd = packed_param_descs(pd)
        params_abs = abstract_params(pd)
        params_spec = _named(mesh, partition_specs(pd, rules, sizes))
        step = make_prefill_step(model)
        jitted = jax.jit(step, in_shardings=(params_spec, batch_spec))
        args = (params_abs, batch_abs)
    else:  # decode
        pd = model.param_descs()
        if packed:
            from repro.quant.packed import packed_param_descs

            pd = packed_param_descs(pd)
        params_abs = abstract_params(pd)
        params_spec = _named(mesh, partition_specs(pd, rules, sizes))
        cd = model.cache_descs(shape.global_batch, shape.seq_len)
        cache_abs = abstract_params(cd)
        cache_spec = _named(mesh, partition_specs(cd, rules, sizes))
        step = make_serve_step(model)
        jitted = jax.jit(
            step,
            in_shardings=(params_spec, cache_spec, batch_spec),
            out_shardings=(None, cache_spec),
            donate_argnums=(1,),
        )
        args = (params_abs, cache_abs, batch_abs)
    return jitted, args, model, shape


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool = False,
             fsdp: bool = True, save: bool = True, tag: str = "",
             rules_override=None, packed: bool = False,
             probes_enabled: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(mesh.devices.shape))
    cfg = get_arch(arch_id)
    shape = SHAPES[shape_name]
    ok, reason = cell_is_supported(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    result = {
        "arch": arch_id, "shape": shape_name, "mesh": mesh_name,
        "chips": n_chips, "supported": ok,
    }
    if not ok:
        result["skip_reason"] = reason
        if save:
            _save(result, tag)
        return result

    from repro.launch.mesh import sharding_rules as _sr
    from repro.models.base import set_activation_rules

    act_rules = dict(_sr(mesh, fsdp=fsdp))
    if rules_override:
        act_rules.update(rules_override)

    t0 = time.time()
    jitted, args, model, shape = build_cell(
        arch_id, shape_name, mesh, fsdp=fsdp, rules_override=rules_override,
        packed=packed,
    )
    set_activation_rules(act_rules, mesh)
    try:
        with mesh:
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
    finally:
        set_activation_rules(None)

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes_from_hlo(compiled.as_text())

    # ---- scan-trip-count correction (see models/base.py xscan) --------
    # HloCostAnalysis counts while-loop bodies once, so the rolled-scan
    # module under-reports per-layer work.  Compile two reduced-depth
    # probes with every scan fully unrolled and extrapolate linearly:
    #   X(L) = X(g) + (L/g - 1) * (X(2g) - X(g))
    from repro.models.base import set_scan_unroll

    cfg_full = get_arch(arch_id)
    g = probe_granularity(cfg_full)
    ratio = cfg_full.n_layers // g
    probes = []
    set_scan_unroll(True)
    set_activation_rules(act_rules, mesh)
    try:
        for mult in (1, 2) if probes_enabled else ():
            pj, pargs, _, _ = build_cell(
                arch_id, shape_name, mesh, fsdp=fsdp,
                rules_override=rules_override,
                cfg_override=probe_config(cfg_full, mult),
                packed=packed,
            )
            with mesh:
                pc = pj.lower(*pargs).compile()
            pcost = pc.cost_analysis()
            pcoll = collective_bytes_from_hlo(pc.as_text())
            probes.append({
                "flops": float(pcost.get("flops", 0.0)),
                "bytes": float(pcost.get("bytes accessed", 0.0)),
                "coll": pcoll["total"],
            })
    finally:
        set_scan_unroll(False)
        set_activation_rules(None)
    t_probe = time.time() - t0 - t_lower - t_compile

    def extrap(key):
        if not probes:  # probes disabled: report the (scan-undercounted)
            return {"flops": float(cost.get("flops", 0.0)),
                    "bytes": float(cost.get("bytes accessed", 0.0)),
                    "coll": coll["total"]}[key]
        x1, x2 = probes[0][key], probes[1][key]
        return x1 + (ratio - 1) * (x2 - x1)

    flops_dev = extrap("flops")
    bytes_dev = extrap("bytes")
    coll_dev = extrap("coll")
    mflops, n_total, n_active = model_flops_estimate(model, shape)

    rt = roofline_terms(
        hlo_flops=flops_dev * n_chips,
        hlo_bytes=bytes_dev * n_chips,
        collective_bytes=coll_dev * n_chips,
        n_chips=n_chips,
    )

    result.update({
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "probe_s": round(t_probe, 2),
        "probes_raw": probes,
        "layer_extrapolation_ratio": ratio,
        "per_device": {
            "flops": flops_dev,
            "bytes_accessed": bytes_dev,
            "collective_bytes_extrapolated": coll_dev,
            "collective_bytes_scan_module": coll,
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
                          + (getattr(mem, "temp_size_in_bytes", 0) or 0),
        },
        "model_flops": mflops,
        "n_params": n_total,
        "n_params_active": n_active,
        "useful_flops_ratio": mflops / max(flops_dev * n_chips, 1.0),
        "roofline": rt,
    })
    if save:
        _save(result, tag)
    return result


def _save(result: dict, tag: str = ""):
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    name = f"{result['arch']}__{result['shape']}__{result['mesh']}{suffix}.json"
    (RESULTS_DIR / name).write_text(json.dumps(result, indent=2, default=str))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="all 40 cells on this mesh")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--packed", action="store_true",
                    help="QSQ bit-plane weights for decode/prefill shapes")
    ap.add_argument("--no-probes", action="store_true",
                    help="skip the unrolled cost probes (pass/fail sweeps)")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    cells = (
        [(a, s) for a in ARCH_IDS for s in SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    for arch, shp in cells:
        try:
            r = run_cell(arch, shp, multi_pod=args.multi_pod,
                         fsdp=not args.no_fsdp, tag=args.tag,
                         packed=args.packed,
                         probes_enabled=not args.no_probes)
        except Exception as e:  # noqa: BLE001 — report and continue the sweep
            print(f"FAIL {arch} {shp}: {type(e).__name__}: {e}")
            continue
        if not r["supported"]:
            print(f"SKIP {arch} {shp}: {r['skip_reason']}")
        else:
            rt = r["roofline"]
            print(
                f"OK {arch} {shp} mesh={r['mesh']} "
                f"compile={r['compile_s']}s "
                f"compute={rt['compute_s']:.3e}s memory={rt['memory_s']:.3e}s "
                f"coll={rt['collective_s']:.3e}s dom={rt['dominant']} "
                f"frac={rt['roofline_fraction']:.2f} "
                f"useful={r['useful_flops_ratio']:.2f}"
            )


if __name__ == "__main__":
    main()

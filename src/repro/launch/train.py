"""Training launcher: --arch <id> [--smoke] [--steps N] [--ckpt DIR].

On this CPU container it trains the smoke config of any arch (or smollm-135m
reduced) on the synthetic LM stream; on a real pod the same entry point runs
under the production mesh with the full config.
"""
from __future__ import annotations

import argparse

import jax

from repro.checkpoint import CheckpointConfig
from repro.configs import ARCH_IDS, get_arch
from repro.data.pipeline import LMDataConfig, lm_batch
from repro.models.api import Model
from repro.optim import AdamWConfig, GradCompressionConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm_135m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=args.smoke)
    model = Model(cfg)
    data_cfg = LMDataConfig(vocab=cfg.vocab, seq_len=args.seq,
                            global_batch=args.batch)

    tcfg = TrainerConfig(
        total_steps=args.steps,
        log_every=max(args.steps // 10, 1),
        opt=AdamWConfig(lr=1e-3),
        compression=GradCompressionConfig(enabled=args.grad_compression),
        checkpoint=CheckpointConfig(directory=args.ckpt) if args.ckpt else None,
    )
    trainer = Trainer(model, tcfg, lambda step: lm_batch(data_cfg, step))
    state, last = trainer.run()
    for m in trainer.metrics_log:
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}  {m['sec_per_step']*1e3:.0f} ms")
    print(f"done at step {last}; devices={jax.device_count()}")


if __name__ == "__main__":
    main()

"""Serving launcher: --arch <id> [--wire PATH] [--prompts ...].

Loads exact params (fresh init on this CPU container) or a QSQ wire
artifact and serves batched greedy decoding through the ServeEngine.
On a real pod the same entry point builds the production mesh and shards
params/caches with launch/mesh.py rules (see launch/dryrun.py for the
lowering path that proves those shardings compile).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.core.policy import QuantPolicy
from repro.core.qsq import QSQConfig
from repro.models.api import Model
from repro.models.base import init_params
from repro.quant import pack_pytree_wire, quantize_pytree
from repro.serve import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm_135m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--wire", action="store_true",
                    help="round-trip the model through the QSQ wire format")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=args.smoke)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_descs())

    if args.wire:
        wire = pack_pytree_wire(quantize_pytree(
            params, QuantPolicy(base=QSQConfig(group_size=16, refit_alpha=True),
                                min_numel=512)))
        engine = ServeEngine.from_wire(model, wire, ServeConfig(batch_slots=args.slots))
        print("loaded from QSQ wire artifact (3-bit + scalars, shift/scale decode)")
    else:
        engine = ServeEngine(model, params, ServeConfig(batch_slots=args.slots))

    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, size=rng.randint(2, 6)).tolist()
               for _ in range(min(args.slots, 3))]
    t0 = time.time()
    outs = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    for p, o in zip(prompts, outs):
        print(f"  {p} -> {o}")
    n = len(prompts) * args.max_new
    print(f"{n} tokens in {dt:.2f}s ({n / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()

"""Serving launcher: --arch <id> [--wire [--dense]] [--max-new N].

Loads exact params (fresh init on this CPU container) or round-trips the
model through the QSQ wire artifact and serves batched greedy decoding
through the ServeEngine.  With --wire the engine keeps matmul weights in
3-bit bit-plane form end-to-end (add --dense to decode everything at load
and compare).  On a real pod the same entry point builds the production
mesh and shards params/caches with launch/mesh.py rules (see
launch/dryrun.py for the lowering path that proves those shardings
compile).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.core.policy import QuantPolicy
from repro.core.qsq import QSQConfig
from repro.models.api import Model
from repro.models.base import init_params
from repro.quant import quantize_pytree, pack_pytree_wire, tree_bits_report
from repro.serve import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm_135m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--wire", action="store_true",
                    help="round-trip the model through the QSQ wire format")
    ap.add_argument("--dense", action="store_true",
                    help="with --wire: decode the whole tree at load instead "
                         "of serving packed bit-planes")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=args.smoke)
    model = Model(cfg)
    descs = model.param_descs()
    params = init_params(jax.random.PRNGKey(0), descs)

    if args.wire:
        qp = quantize_pytree(
            params,
            QuantPolicy(base=QSQConfig(group_size=16, refit_alpha=True),
                        min_numel=512),
            descs,
        )
        wire = pack_pytree_wire(qp)
        engine = ServeEngine.from_wire(
            model, wire,
            ServeConfig(batch_slots=args.slots, packed=not args.dense),
        )
        rep = tree_bits_report(engine.params)
        print(
            f"loaded from QSQ wire artifact "
            f"({engine.n_packed_leaves} leaves served packed, "
            f"{rep['savings'] * 100:.0f}% below f32)"
        )
    else:
        engine = ServeEngine(model, params, ServeConfig(batch_slots=args.slots))

    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, size=rng.randint(2, 6)).tolist()
               for _ in range(min(args.slots, 3))]
    t0 = time.time()
    outs = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    for p, o in zip(prompts, outs):
        print(f"  {p} -> {o}")
    n = len(prompts) * args.max_new
    print(f"{n} tokens in {dt:.2f}s ({n / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()

"""Serving launcher: --arch <id> [--wire [--quality T] [--dense]].

Loads exact params (fresh init on this CPU container) or compresses the
model into a quality-dialed EdgeArtifact and serves batched decoding
through the facade (`repro.api`).  With --wire the engine keeps matmul
weights in 3-bit bit-plane form end-to-end; --quality picks the serving
tier (lower tiers drop LSB bit-planes from the least-sensitive layers —
no re-quantization); add --dense to decode everything at load and compare.
On a real pod the same entry point builds the production mesh and shards
params/caches with launch/mesh.py rules (see launch/dryrun.py for the
lowering path that proves those shardings compile).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import api
from repro.configs import ARCH_IDS, get_arch
from repro.models.api import Model
from repro.models.base import init_params
from repro.quant import tree_bits_report
from repro.serve import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm_135m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--wire", action="store_true",
                    help="compress to the QSQ wire artifact and serve it")
    ap.add_argument("--quality", default="hi",
                    choices=api.DEFAULT_TIERS.names(),
                    help="with --wire: serving tier (plane truncation, "
                         "no re-quantization)")
    ap.add_argument("--dense", action="store_true",
                    help="with --wire: decode the whole tree at load instead "
                         "of serving packed bit-planes")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompts", type=int, default=None,
                    help="number of synthetic prompts to serve "
                         "(default: min(--slots, 3))")
    args = ap.parse_args()

    if args.slots < 1:
        ap.error("--slots must be >= 1")
    if args.prompts is None:
        args.prompts = min(args.slots, 3)
    elif not 1 <= args.prompts <= args.slots:
        ap.error(f"--prompts must be in [1, --slots={args.slots}]; "
                 f"got {args.prompts}")
    if not args.wire and (args.quality != "hi" or args.dense):
        ap.error("--quality/--dense only apply with --wire")

    cfg = get_arch(args.arch, smoke=args.smoke)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_descs())

    if args.wire:
        artifact = api.compress(model, params)
        engine = artifact.engine(
            quality=args.quality, batch_slots=args.slots,
            packed=not args.dense,
        )
        rep = tree_bits_report(engine.params)
        print(
            f"serving tier {args.quality!r} from the QSQ wire artifact "
            f"({engine.n_packed_leaves} leaves served packed, "
            f"{rep['savings'] * 100:.0f}% below f32)"
        )
    else:
        engine = ServeEngine(model, params, ServeConfig(batch_slots=args.slots))

    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, size=rng.randint(2, 6)).tolist()
               for _ in range(args.prompts)]
    t0 = time.time()
    outs = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    for p, o in zip(prompts, outs):
        print(f"  {p} -> {o}")
    n = len(prompts) * args.max_new
    print(f"{n} tokens in {dt:.2f}s ({n / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()

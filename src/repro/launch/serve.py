"""Serving launcher: --arch <id> [--wire [--quality T] [--dense]] [--stream].

Loads exact params (fresh init on this CPU container) or compresses the
model into a quality-dialed EdgeArtifact and serves batched decoding
through the facade (`repro.api`).  With --wire the engine keeps matmul
weights in 3-bit bit-plane form end-to-end; --quality picks the serving
tier (lower tiers drop LSB bit-planes from the least-sensitive layers —
no re-quantization); add --dense to decode everything at load and compare.

``--stream`` drives the continuous-batching scheduler instead of one
static generate(): synthetic prompts arrive staggered (every
``--arrival-every`` engine steps), are submitted mid-decode, and tokens
print as each request finishes — along with per-request waiting time and
latency in steps, the numbers a static batch cannot hit because a new
prompt would wait for the whole batch to drain.  With ``--wire`` add
``--mixed-tiers`` to cycle each arrival through the artifact's quality
tiers (hi/mid/lo/...): every request is prefilled and decoded at its OWN
tier inside the one shared dispatch — per-request quality, no retrace,
no param-tree swap.

Robust-serving knobs (with ``--stream``): ``--deadline`` ages requests on
the engine's cost clock and evicts them mid-decode once past it
(TIMED_OUT, partial tokens kept); ``--slo`` turns on QualityShed
admission (downgrade hi->mid->lo against the budget, shed past it);
``--max-queue`` bounds the scheduler queue (REJECTED beyond it).  Every
terminal request prints its typed finish_reason — nothing hangs.

``--speculate TIER[:K]`` (with ``--wire --stream``) turns on
self-speculative decoding: every request drafts K tokens per round at
TIER — a cheaper plane mask over the SAME packed weights, streamed via
the demand floor — then one hi-tier dispatch verifies the window and
keeps the longest agreeing prefix.  Tokens are identical to plain
serving; the wins print per request as drafted/accepted counters and as
the stream's acceptance rate and weight-bytes per accepted token.

On a real pod the same entry point builds the production mesh and shards
params/caches with launch/mesh.py rules (see launch/dryrun.py for the
lowering path that proves those shardings compile).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import api
from repro.configs import ARCH_IDS, get_arch
from repro.models.api import Model
from repro.models.base import init_params
from repro.quant import tree_bits_report
from repro.serve import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="smollm_135m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--wire", action="store_true",
                    help="compress to the QSQ wire artifact and serve it")
    ap.add_argument("--quality", default="hi",
                    choices=api.DEFAULT_TIERS.names(),
                    help="with --wire: serving tier (plane truncation, "
                         "no re-quantization)")
    ap.add_argument("--dense", action="store_true",
                    help="with --wire: decode the whole tree at load instead "
                         "of serving packed bit-planes")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompts", type=int, default=None,
                    help="number of synthetic prompts to serve "
                         "(default: min(--slots, 3))")
    ap.add_argument("--stream", action="store_true",
                    help="continuous batching: submit prompts at staggered "
                         "arrivals and admit them mid-decode (attention "
                         "families, greedy)")
    ap.add_argument("--arrival-every", type=int, default=2,
                    help="with --stream: engine steps between arrivals")
    ap.add_argument("--mixed-tiers", action="store_true",
                    help="with --wire --stream: cycle arrivals through the "
                         "artifact's quality tiers — each request served "
                         "at its own tier in the one shared dispatch")
    ap.add_argument("--deadline", type=float, default=None,
                    help="with --stream: per-request deadline in cost-clock "
                         "units — queued requests past it are cancelled, "
                         "in-flight ones evicted mid-decode (TIMED_OUT)")
    ap.add_argument("--slo", type=float, default=None,
                    help="with --stream: enable QualityShed admission — "
                         "downgrade tiers to hold estimated latency under "
                         "this budget (cost-clock units), shed when even "
                         "the lowest tier misses it")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="with --stream: bound the scheduler queue; "
                         "arrivals beyond it finish as REJECTED")
    ap.add_argument("--speculate", default=None, metavar="TIER[:K]",
                    help="with --wire --stream: self-speculative decoding — "
                         "draft K tokens/round (default 4) at TIER (a "
                         "cheaper plane mask of the same packed weights), "
                         "verify in one serving-tier dispatch; tokens stay "
                         "identical to plain serving")
    args = ap.parse_args()

    if args.slots < 1:
        ap.error("--slots must be >= 1")
    if args.prompts is None:
        # streams queue beyond the slot count — that's the point
        args.prompts = args.slots + 2 if args.stream else min(args.slots, 3)
    elif args.prompts < 1:
        ap.error(f"--prompts must be >= 1; got {args.prompts}")
    elif not args.stream and args.prompts > args.slots:
        ap.error(f"--prompts must be in [1, --slots={args.slots}] without "
                 f"--stream (a static batch cannot queue); got {args.prompts}")
    if args.arrival_every < 1:
        ap.error("--arrival-every must be >= 1")
    if not args.wire and (args.quality != "hi" or args.dense):
        ap.error("--quality/--dense only apply with --wire")
    if args.mixed_tiers and not (args.wire and args.stream):
        ap.error("--mixed-tiers needs --wire --stream (per-request quality "
                 "rides the continuous scheduler on the packed artifact)")
    if args.mixed_tiers and args.dense:
        ap.error("--mixed-tiers needs packed serving (drop --dense)")
    if not args.stream and (args.deadline is not None or args.slo is not None
                            or args.max_queue is not None):
        ap.error("--deadline/--slo/--max-queue only apply with --stream "
                 "(a static generate() has no queue to protect)")
    if args.deadline is not None and args.deadline <= 0:
        ap.error("--deadline must be > 0")
    if args.max_queue is not None and args.max_queue < 0:
        ap.error("--max-queue must be >= 0")
    speculate = None
    if args.speculate is not None:
        if not (args.wire and args.stream) or args.dense:
            ap.error("--speculate needs --wire --stream packed serving "
                     "(the draft tier is a plane mask over the packed "
                     "artifact inside the continuous scheduler)")
        if args.mixed_tiers:
            ap.error("--speculate cannot combine with --mixed-tiers: the "
                     "draft tier must sit strictly below every request's "
                     "serving tier, which a full tier cycle violates")
        draft, _, kstr = args.speculate.partition(":")
        names = api.DEFAULT_TIERS.names()
        if draft not in names:
            ap.error(f"--speculate tier must be one of {names}; got "
                     f"{draft!r}")
        if names.index(draft) <= names.index(args.quality):
            ap.error(f"--speculate tier {draft!r} must sit strictly below "
                     f"the serving tier {args.quality!r}")
        try:
            k = int(kstr) if kstr else 4
        except ValueError:
            ap.error(f"--speculate window must be an integer; got {kstr!r}")
        if k < 1:
            ap.error(f"--speculate window must be >= 1; got {k}")
        speculate = api.SpecConfig(draft, k)

    cfg = get_arch(args.arch, smoke=args.smoke)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_descs())

    admission = None
    if args.slo is not None:
        admission = api.QualityShed(api.SLOBudget(latency=args.slo,
                                                  max_queue=args.max_queue))
    if args.wire:
        artifact = api.compress(model, params)
        engine = artifact.engine(
            quality=args.quality, batch_slots=args.slots,
            packed=not args.dense, admission=admission,
            max_queue=args.max_queue,
        )
        rep = tree_bits_report(engine.params)
        print(
            f"serving tier {args.quality!r} from the QSQ wire artifact "
            f"({engine.n_packed_leaves} leaves served packed, "
            f"{rep['savings'] * 100:.0f}% below f32)"
        )
    else:
        engine = ServeEngine(model, params, ServeConfig(
            batch_slots=args.slots, admission=admission,
            max_queue=args.max_queue))

    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, size=rng.randint(2, 6)).tolist()
               for _ in range(args.prompts)]
    if args.stream:
        tiers = None
        if args.mixed_tiers:
            if not engine.per_request_quality:
                ap.error("this artifact/config cannot serve per-request "
                         "tiers (needs a greedy attention family AND an "
                         "artifact with a sensitivity ranking — rebuild a "
                         "bare wire with repro.api.compress)")
            names = engine.tier_names
            tiers = [names[i % len(names)] for i in range(len(prompts))]
        if speculate is not None and not engine.per_request_quality:
            ap.error("--speculate needs per-request quality serving (a "
                     "greedy attention family and an artifact with a "
                     "sensitivity ranking)")
        _serve_stream(engine, prompts, args.max_new, args.arrival_every,
                      tiers=tiers, deadline=args.deadline,
                      speculate=speculate)
        return
    t0 = time.time()
    outs = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    for p, o in zip(prompts, outs, strict=True):
        print(f"  {p} -> {o}")
    n = len(prompts) * args.max_new
    print(f"{n} tokens in {dt:.2f}s ({n / dt:.1f} tok/s)")


def _serve_stream(engine, prompts, max_new: int, arrival_every: int,
                  tiers=None, deadline: float | None = None,
                  speculate=None) -> None:
    """Feed staggered arrivals through submit()/step()/poll(): prompt i
    arrives at step i * arrival_every and joins the running decode as soon
    as a slot frees — no batch flush.  ``tiers`` (one name per prompt)
    submits each request at its own quality tier into the shared dispatch.
    ``speculate`` (a SpecConfig) drafts every request at a cheap tier and
    verifies at its serving tier; accepted/drafted counters print per
    request.  Prints each request as it terminates with its typed
    finish_reason (done / timed_out / cancelled / shed / rejected),
    realized tier, waiting time (queued steps) and latency (arrival ->
    last token)."""
    t0 = time.time()
    pending = list(enumerate(prompts))
    rid_to_prompt = {}
    while pending or engine.has_work:
        step_idx = engine.step_count
        while pending and pending[0][0] * arrival_every <= step_idx:
            i, p = pending.pop(0)
            tier = tiers[i] if tiers is not None else None
            rid = engine.submit(p, max_new=max_new, quality=tier,
                                deadline=deadline, speculate=speculate)
            rid_to_prompt[rid] = p
            tag = f" @{tier}" if tier is not None else ""
            print(f"  step {step_idx:3d}  submit    r{rid}{tag} {p}")
        engine.step()
        for rid, st in engine.poll().items():
            tag = f" @{st.quality}" if st.quality is not None else ""
            reason = st.finish_reason.value
            where = f"step {st.finished:3d}" if st.finished is not None \
                else f"step {step_idx:3d}"
            line = f"  {where}  {reason:9s} r{rid}{tag} {rid_to_prompt[rid]}"
            if st.tokens:
                line += f" -> {st.tokens}"
            if st.drafted:
                line += f" [spec {st.accepted}/{st.drafted} accepted]"
            if st.waiting is not None and st.latency is not None:
                line += f" (waited {st.waiting}, latency {st.latency} steps)"
            elif st.detail:
                line += f" ({st.detail})"
            print(line)
    dt = time.time() - t0
    done = [r for r in engine.completed_requests.values()
            if r.waiting is not None and r.latency is not None]
    n = sum(len(r.out) for r in done)
    mean_wait = np.mean([r.waiting for r in done]) if done else 0.0
    mean_lat = np.mean([r.latency for r in done]) if done else 0.0
    print(f"{n} tokens / {len(rid_to_prompt)} requests in {dt:.2f}s "
          f"({n / dt:.1f} tok/s; mean wait {mean_wait:.1f} steps, "
          f"mean latency {mean_lat:.1f} steps)")
    if speculate is not None:
        st = engine.stream_stats()
        print(f"speculative: drafted {st['drafted']}, accepted "
              f"{st['accepted']} (rate {st['acceptance_rate']:.3f}); "
              f"{st['bytes_per_token']:.0f} weight bytes per accepted "
              f"token ({st['read_frac']:.2f} of full-plane reads)")


if __name__ == "__main__":
    main()

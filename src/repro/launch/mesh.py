"""Production mesh + logical-axis sharding rules.

Single pod:  (16, 16)     axes ("data", "model")   — 256 chips
Multi pod:   (2, 16, 16)  axes ("pod", "data", "model") — 512 chips

`make_production_mesh` is a FUNCTION so importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

from typing import Mapping

import jax


def _mesh(shape, axes):
    # jax.sharding.AxisType only exists on newer jax; older versions default
    # every axis to Auto, which is exactly what we want.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many devices the test process has."""
    return _mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Mesh axes that carry the batch (pod is an outer DP axis)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def sharding_rules(mesh, *, fsdp: bool = True) -> Mapping[str, tuple]:
    """Logical axis name -> mesh axes.

    * model-parallel dims (heads / mlp / vocab / experts) -> "model"
    * FSDP: the residual "embed" dim of weight matrices shards over "data"
      (+"pod" when present), zero-3 style — params are gathered per layer
      inside the scan.  Disable for small models that fit replicated.
    * batch -> ("pod", "data"); decode kv-cache seq -> "model" (long-context
      caches are the dominant decode-state and shard over the model axis).
    """
    dp = data_axes(mesh)
    rules = {
        "batch": dp,
        "vocab": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "mlp": ("model",),
        "experts": ("model",),
        "heads_inner": ("model",),  # mamba d_inner / ssm heads
        "seq_kv": ("model",),  # decode caches: shard the sequence dim
        "seq_act": (),  # context parallelism (activations' seq dim) — opt-in
        "embed": dp if fsdp else (),
        "layers": (),
    }
    return rules


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape, strict=True))

"""smollm-135m — small llama-arch dense decoder (the e2e training example).

[hf:HuggingFaceTB/SmolLM-135M; hf].  30L d_model=576 9H (GQA kv=3)
d_ff=1536 vocab=49152.  9 heads do not divide the 16-wide model axis;
attention stays replicated under the divisibility fallback (DESIGN.md).
"""
import jax.numpy as jnp

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv=3,
    d_ff=1536,
    vocab=49152,
    source="hf:HuggingFaceTB/SmolLM-135M; hf",
)

# Reduced same-family config for CPU smoke tests (one fwd/train step).
SMOKE_CONFIG = ArchConfig(
    name="smollm-smoke",
    family="dense",
    n_layers=2,
    d_model=48,
    n_heads=3,
    n_kv=1,
    d_ff=96,
    vocab=256,
    dtype=jnp.float32,
    remat=False,
)

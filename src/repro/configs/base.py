"""Architecture + shape configuration schema and the --arch registry."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Jamba-style interleave: each block of ``period`` layers has one
    attention layer (index 0) and ``period - 1`` mamba layers; FFNs alternate
    dense / MoE starting with dense at layer 0 (=> MoE every other layer)."""

    period: int = 8
    moe_every: int = 2


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | cnn
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    window: int | None = None  # sliding-window attention
    rope_theta: float = 10000.0
    moe: MoEConfig | None = None
    # ssm (mamba2 / hybrid)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 256
    hybrid: HybridConfig | None = None
    # encoder-decoder (whisper)
    enc_layers: int = 0
    enc_seq: int = 1500  # precomputed frame embeddings from the stub frontend
    # vlm
    cross_every: int = 0  # a gated cross-attn block after every N self layers
    vision_tokens: int = 1024  # precomputed patch embeddings from the stub
    # numerics / scale
    dtype: Any = jnp.bfloat16
    remat: bool = True
    # notes from the public source
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the long_500k decode shape?"""
        return self.family in ("ssm", "hybrid") or self.window is not None

    @property
    def has_decode(self) -> bool:
        return self.family != "cnn"


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "mixtral_8x22b",
    "qwen3_moe_30b_a3b",
    "mamba2_1_3b",
    "deepseek_7b",
    "smollm_135m",
    "phi4_mini_3_8b",
    "qwen3_14b",
    "jamba_1_5_large_398b",
    "whisper_tiny",
    "llama_3_2_vision_11b",
]


def canonical(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_arch(arch_id: str, smoke: bool = False) -> ArchConfig:
    """Load configs/<id>.py and return CONFIG (or SMOKE_CONFIG)."""
    mod = importlib.import_module(f"repro.configs.{canonical(arch_id)}")
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def cell_is_supported(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(supported, reason-if-not) for an (arch x shape) cell."""
    if cfg.family == "cnn":
        return False, "cnn archs are trained directly; LM shapes do not apply"
    if shape.kind == "decode" and not cfg.has_decode:
        return False, "no decode step for this family"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full quadratic attention; 500k decode skipped (DESIGN.md §4)"
    return True, ""

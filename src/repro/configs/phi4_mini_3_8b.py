"""phi4-mini-3.8b — dense decoder, RoPE+SwiGLU+GQA, 200k vocab.

[arXiv:2412.08905; hf].  32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064.
"""
import jax.numpy as jnp

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv=8,
    d_ff=8192,
    vocab=200064,
    source="arXiv:2412.08905; hf",
)

# Reduced same-family config for CPU smoke tests (one fwd/train step).
SMOKE_CONFIG = ArchConfig(
    name="phi4-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_ff=128,
    vocab=512,
    dtype=jnp.float32,
    remat=False,
)

"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 interleave with MoE.

[arXiv:2403.19887; hf].  72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2 every other layer; 9 blocks of 8 layers,
1 attention + 7 mamba per block.  SSD mixer: d_inner 16384, 128 heads
of dim 128, 8 groups, state 128.
"""
import jax.numpy as jnp

from repro.configs.base import ArchConfig, HybridConfig, MoEConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    moe=MoEConfig(n_experts=16, top_k=2),
    hybrid=HybridConfig(period=8, moe_every=2),
    ssm_state=128,
    ssm_head_dim=128,
    ssm_groups=8,
    source="arXiv:2403.19887; hf",
)

# Reduced same-family config for CPU smoke tests (one fwd/train step).
SMOKE_CONFIG = ArchConfig(
    name="jamba-smoke",
    family="hybrid",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    moe=MoEConfig(n_experts=4, top_k=2),
    hybrid=HybridConfig(period=8, moe_every=2),
    ssm_state=16,
    ssm_head_dim=32,
    ssm_groups=2,
    ssm_chunk=16,
    dtype=jnp.float32,
    remat=False,
)

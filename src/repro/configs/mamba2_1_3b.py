"""mamba2-1.3b — attention-free SSD (state-space duality) LM.

[arXiv:2405.21060; unverified].  48L d_model=2048, vocab=50280,
ssm_state=128, head_dim 64, d_inner = 2*d_model.
"""
import jax.numpy as jnp

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=1,
    n_kv=1,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_groups=1,
    source="arXiv:2405.21060",
)

# Reduced same-family config for CPU smoke tests (one fwd/train step).
SMOKE_CONFIG = ArchConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=1,
    n_kv=1,
    d_ff=0,
    vocab=256,
    ssm_state=16,
    ssm_head_dim=16,
    ssm_groups=1,
    ssm_chunk=16,
    dtype=jnp.float32,
    remat=False,
)

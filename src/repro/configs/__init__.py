"""Per-architecture configs (--arch <id>) + the paper's own CNNs."""
from repro.configs.base import (
    ARCH_IDS, SHAPES, ArchConfig, MoEConfig, HybridConfig, ShapeConfig,
    get_arch, canonical, cell_is_supported,
)

__all__ = ["ARCH_IDS", "SHAPES", "ArchConfig", "MoEConfig", "HybridConfig",
           "ShapeConfig", "get_arch", "canonical", "cell_is_supported"]

"""Per-architecture configs (--arch <id>) + the paper's own CNNs."""
from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    ArchConfig,
    HybridConfig,
    MoEConfig,
    ShapeConfig,
    canonical,
    cell_is_supported,
    get_arch,
)

__all__ = ["ARCH_IDS", "SHAPES", "ArchConfig", "MoEConfig", "HybridConfig",
           "ShapeConfig", "get_arch", "canonical", "cell_is_supported"]

"""qwen3-moe-30b-a3b — 128-expert top-8 fine-grained MoE with qk_norm.

[hf:Qwen/Qwen3-30B-A3B; hf].  48L d_model=2048 32H (GQA kv=4, head_dim 128)
per-expert d_ff=768, vocab=151936, MoE 128e top-8.
"""
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv=4,
    head_dim=128,
    d_ff=768,
    vocab=151936,
    qk_norm=True,
    moe=MoEConfig(n_experts=128, top_k=8),
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)

# Reduced same-family config for CPU smoke tests (one fwd/train step).
SMOKE_CONFIG = ArchConfig(
    name="qwen3-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    head_dim=16,
    d_ff=32,
    vocab=256,
    qk_norm=True,
    moe=MoEConfig(n_experts=8, top_k=2),
    dtype=jnp.float32,
    remat=False,
)

"""mixtral-8x22b — 8-expert top-2 MoE with sliding-window attention.

[arXiv:2401.04088; hf].  56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8e top-2, SWA window 4096.
"""
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    head_dim=128,
    d_ff=16384,
    vocab=32768,
    moe=MoEConfig(n_experts=8, top_k=2),
    window=4096,
    rope_theta=1e6,
    source="arXiv:2401.04088; hf",
)

# Reduced same-family config for CPU smoke tests (one fwd/train step).
SMOKE_CONFIG = ArchConfig(
    name="mixtral-8x22b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    moe=MoEConfig(n_experts=4, top_k=2),
    window=32,
    dtype=jnp.float32,
    remat=False,
)

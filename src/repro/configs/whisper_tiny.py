"""whisper-tiny — encoder-decoder; conv/mel frontend is a STUB.

[arXiv:2212.04356; unverified].  4+4L d_model=384 6H d_ff=1536 vocab=51865.
input_specs() supplies precomputed frame embeddings (B, 1500, 384).
Decode shapes are lowered mechanically (the real model caps at 448
positions) — recorded by the dry-run sweep; long_500k skipped (full attention).
"""
import jax.numpy as jnp

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    enc_layers=4,
    enc_seq=1500,
    d_model=384,
    n_heads=6,
    n_kv=6,
    d_ff=1536,
    vocab=51865,
    source="arXiv:2212.04356",
)

# Reduced same-family config for CPU smoke tests (one fwd/train step).
SMOKE_CONFIG = ArchConfig(
    name="whisper-smoke",
    family="encdec",
    n_layers=2,
    enc_layers=2,
    enc_seq=32,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_ff=128,
    vocab=256,
    dtype=jnp.float32,
    remat=False,
)

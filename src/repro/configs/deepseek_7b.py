"""deepseek-7b — llama-arch dense decoder (MHA: kv == heads).

[arXiv:2401.02954; hf].  30L d_model=4096 32H (kv=32) d_ff=11008 vocab=102400.
"""
import jax.numpy as jnp

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv=32,
    d_ff=11008,
    vocab=102400,
    source="arXiv:2401.02954; hf",
)

# Reduced same-family config for CPU smoke tests (one fwd/train step).
SMOKE_CONFIG = ArchConfig(
    name="deepseek-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=4,
    d_ff=128,
    vocab=256,
    dtype=jnp.float32,
    remat=False,
)

"""qwen3-14b — dense decoder with qk_norm.

[hf:Qwen/Qwen3-14B; hf].  40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936.
"""
import jax.numpy as jnp

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv=8,
    head_dim=128,
    d_ff=17408,
    vocab=151936,
    qk_norm=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-14B; hf",
)

# Reduced same-family config for CPU smoke tests (one fwd/train step).
SMOKE_CONFIG = ArchConfig(
    name="qwen3-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv=2,
    head_dim=16,
    d_ff=128,
    vocab=256,
    qk_norm=True,
    dtype=jnp.float32,
    remat=False,
)

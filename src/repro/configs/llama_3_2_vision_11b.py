"""llama-3.2-vision-11b — decoder with gated cross-attn blocks every 5 layers.

[hf:meta-llama/Llama-3.2-11B-Vision; unverified].  40L d_model=4096 32H
(GQA kv=8) d_ff=14336 vocab=128256.  Vision tower is a STUB: input_specs()
supplies precomputed patch embeddings (B, 1024, 4096).
"""
import jax.numpy as jnp

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=14336,
    vocab=128256,
    cross_every=5,
    vision_tokens=1024,
    rope_theta=5e5,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)

# Reduced same-family config for CPU smoke tests (one fwd/train step).
SMOKE_CONFIG = ArchConfig(
    name="llama-vision-smoke",
    family="vlm",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv=2,
    d_ff=128,
    vocab=256,
    cross_every=2,
    vision_tokens=16,
    dtype=jnp.float32,
    remat=False,
)

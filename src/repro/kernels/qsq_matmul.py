"""Pallas TPU kernel: fused QSQ dequant + matmul.

This is the paper's on-chip shift-and-scale decoder (Table II) realized for
TPU: weights live in HBM as 3-bit codes (bit-plane packed, 3 int32 words per
32 weights) plus one f32 scalar per group of G weights.  The kernel streams
code tiles into VMEM, unpacks them with shifts/masks in VREGs (the "decoder
hardware"), applies sign * 2^k * alpha (Table II rows as arithmetic), and
feeds the MXU — so dense f32/bf16 weights never touch HBM.

HBM traffic for weights drops from 16 bits/weight (bf16) to
3 + 32/G bits/weight (= 5 bits at G=16, 3.5 bits at G=64): a 3.2-4.6x cut in
the weight-streaming memory-roofline term, which dominates decode-shape
inference (measured by benchmarks/bench_kernels.py and
benchmarks/bench_serve.py; see README.md §Performance).

Layout:
  x       (M, K)            bf16/f32   activations
  planes  (K//32, 3, N)     int32      bit-plane packed 3-bit codes
  scales  (K//G, N)         f32        per-group scalars (group along K)
  out     (M, N)            f32

Grid: (M/bm, N/bn, K/bk), K innermost (accumulation, "arbitrary" semantics).
Default tile (bm=256, bk=512, bn=256) VMEM footprint:
  x 256x512xbf16 = 256 KiB, planes 16x3x256xi32 = 48 KiB,
  w-unpacked 512x256xf32 = 512 KiB, acc 256x256xf32 = 256 KiB
  => ~1.1 MiB/step, double-buffered ~2.2 MiB << 16 MiB VMEM.  All matmul
  dims are multiples of 128 (MXU-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import MASK_VARIANTS

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

PLANE = 32  # codes per bit-plane word (matches codec.PLANE_GROUP)


def _decode_codes(codes: jax.Array) -> jax.Array:
    """Table II: 3-bit code -> level value, as branch-free integer math.

    0->0, 1->+1, 2->+2, 3->+4, 4->-1, 5->-2, 6->-4, 7->0 (unused).
    """
    c = codes.astype(jnp.int32)
    pos = (c >= 1) & (c <= 3)
    neg = (c >= 4) & (c <= 6)
    # exponent: positive codes 1..3 -> 0..2; negative codes 4..6 -> 0..2
    exp = jnp.where(pos, c - 1, jnp.where(neg, c - 4, 0))
    mag = jnp.int32(1) << exp
    return jnp.where(pos, mag, jnp.where(neg, -mag, 0))


def _unpack_planes(planes_blk: jax.Array, bk: int, bn: int) -> jax.Array:
    """(bk//32, 3, bn) int32 bit-planes -> (bk, bn) int32 codes."""
    g = bk // PLANE
    # bit position j within each 32-code word, as an iota over a new axis
    j = jax.lax.broadcasted_iota(jnp.int32, (g, PLANE, bn), dimension=1)
    code = jnp.zeros((g, PLANE, bn), dtype=jnp.int32)
    for p in range(3):
        word = planes_blk[:, p, :]  # (g, bn)
        bit = (jax.lax.shift_right_logical(word[:, None, :], j)) & 1
        code = code | (bit << p)
    return code.reshape(bk, bn)


def _qsq_matmul_kernel(x_ref, planes_ref, scales_ref, o_ref, *, bk: int, group_size: int):
    bm, _ = x_ref.shape
    bn = o_ref.shape[1]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = _unpack_planes(planes_ref[...], bk, bn)          # (bk, bn) int32
    levels = _decode_codes(codes).astype(jnp.float32)        # (bk, bn)
    # broadcast per-group scales down each K-group of rows
    ng = bk // group_size
    lev_g = levels.reshape(ng, group_size, bn)
    w = (lev_g * scales_ref[...][:, None, :]).reshape(bk, bn)
    w = w.astype(x_ref.dtype)
    o_ref[...] += jnp.dot(
        x_ref[...], w, preferred_element_type=jnp.float32
    )


def _qsq_matmul_masked_kernel(
    xs_ref, planes_ref, scales_ref, o_ref, *, bk: int, group_size: int
):
    """Per-row plane-masked GEMM tile (see qsq_matvec._qsq_matvec_masked_kernel
    for the variant-split contract): one weight-tile stream, three static
    mask decodes in VREGs, one dot per variant into the shared output."""
    bn = o_ref.shape[1]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = _unpack_planes(planes_ref[...], bk, bn)          # (bk, bn) int32
    ng = bk // group_size
    sc = scales_ref[...]
    acc = None
    for i, mask in enumerate(MASK_VARIANTS):
        levels = _decode_codes(codes & mask).astype(jnp.float32)
        w = (levels.reshape(ng, group_size, bn) * sc[:, None, :]).reshape(bk, bn)
        d = jnp.dot(
            xs_ref[i], w.astype(xs_ref.dtype), preferred_element_type=jnp.float32
        )
        acc = d if acc is None else acc + d
    o_ref[...] += acc


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "bm", "bk", "bn", "interpret"),
)
def qsq_matmul_masked(
    xs: jax.Array,
    planes: jax.Array,
    scales: jax.Array,
    *,
    group_size: int,
    bm: int = 256,
    bk: int = 512,
    bn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Plane-masked sibling of :func:`qsq_matmul`: xs (3, M, K) -> (M, N) f32.

    xs[i] holds the x rows whose plane mask is ``ref.MASK_VARIANTS[i]``
    (other rows zero).  Same tiling contract as the unmasked kernel."""
    nv, m, kdim = xs.shape
    n = planes.shape[-1]
    if nv != len(MASK_VARIANTS):
        raise ValueError(f"xs leading dim {nv} != {len(MASK_VARIANTS)} mask variants")
    if planes.shape != (kdim // PLANE, 3, n):
        raise ValueError(f"planes shape {planes.shape} != {(kdim // PLANE, 3, n)}")
    if scales.shape != (kdim // group_size, n):
        raise ValueError(f"scales shape {scales.shape} != {(kdim // group_size, n)}")
    bm, bk, bn = min(bm, m), min(bk, kdim), min(bn, n)
    if m % bm or kdim % bk or n % bn:
        raise ValueError(f"shape ({m},{kdim},{n}) not divisible by tile ({bm},{bk},{bn})")
    if bk % PLANE or bk % group_size:
        raise ValueError(f"bk={bk} must be a multiple of 32 and group_size={group_size}")

    grid = (m // bm, n // bn, kdim // bk)
    kernel = functools.partial(_qsq_matmul_masked_kernel, bk=bk, group_size=group_size)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nv, bm, bk), lambda i, j, k: (0, i, k)),
            pl.BlockSpec((bk // PLANE, 3, bn), lambda i, j, k: (k, 0, j)),
            pl.BlockSpec((bk // group_size, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=_COMPILER_PARAMS(dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xs, planes, scales)


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "bm", "bk", "bn", "interpret"),
)
def qsq_matmul(
    x: jax.Array,
    planes: jax.Array,
    scales: jax.Array,
    *,
    group_size: int,
    bm: int = 256,
    bk: int = 512,
    bn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Fused 3-bit dequant + matmul: x (M,K) @ decode(planes, scales) -> (M,N) f32."""
    m, kdim = x.shape
    n = planes.shape[-1]
    if planes.shape != (kdim // PLANE, 3, n):
        raise ValueError(f"planes shape {planes.shape} != {(kdim // PLANE, 3, n)}")
    if scales.shape != (kdim // group_size, n):
        raise ValueError(f"scales shape {scales.shape} != {(kdim // group_size, n)}")
    bm, bk, bn = min(bm, m), min(bk, kdim), min(bn, n)
    if m % bm or kdim % bk or n % bn:
        raise ValueError(f"shape ({m},{kdim},{n}) not divisible by tile ({bm},{bk},{bn})")
    if bk % PLANE or bk % group_size:
        raise ValueError(f"bk={bk} must be a multiple of 32 and group_size={group_size}")

    grid = (m // bm, n // bn, kdim // bk)
    kernel = functools.partial(_qsq_matmul_kernel, bk=bk, group_size=group_size)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // PLANE, 3, bn), lambda i, j, k: (k, 0, j)),
            pl.BlockSpec((bk // group_size, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=_COMPILER_PARAMS(dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, planes, scales)

"""Pallas TPU kernel: fused QSQ dequant + matmul.

This is the paper's on-chip shift-and-scale decoder (Table II) realized for
TPU: weights live in HBM as 3-bit codes (bit-plane packed, 3 int32 words per
32 weights) plus one f32 scalar per group of G weights.  The kernel streams
code tiles into VMEM, unpacks them with shifts/masks in VREGs (the "decoder
hardware"), applies sign * 2^k * alpha (Table II rows as arithmetic), and
feeds the MXU — so dense f32/bf16 weights never touch HBM.

HBM traffic for weights drops from 16 bits/weight (bf16) to
3 + 32/G bits/weight (= 5 bits at G=16, 3.5 bits at G=64): a 3.2-4.6x cut in
the weight-streaming memory-roofline term, which dominates decode-shape
inference (measured by benchmarks/bench_kernels.py and
benchmarks/bench_serve.py; see README.md §Performance).

Layout (plane-interleaved, legacy):
  x       (M, K)            bf16/f32   activations
  planes  (K//32, 3, N)     int32      bit-plane packed 3-bit codes
  scales  (K//G, N)         f32        per-group scalars (group along K)
  out     (M, N)            f32

Layout (plane-major, ``plane_major=True``):
  planes  (3, K//32, N)     int32      MSB-first: plane 0 holds code bit 2

Plane-major is the demand-streaming layout: the planes a tier keeps are a
leading prefix, so a call that demands only ``n_planes`` planes reads a
``(n_planes, bk//32, bn)`` block — the dropped planes never leave HBM.
At n_planes=1 the weight stream is ~1/3 of the full read.

``sign_mag`` selects the wire-v2 sign-magnitude decoder (bit 2 = sign,
bits 1..0 = magnitude index) over the Table II offset decoder.

Grid: (M/bm, N/bn, K/bk), K innermost (accumulation, "arbitrary" semantics).
Default tile (bm=256, bk=512, bn=256) VMEM footprint:
  x 256x512xbf16 = 256 KiB, planes 16x3x256xi32 = 48 KiB,
  w-unpacked 512x256xf32 = 512 KiB, acc 256x256xf32 = 256 KiB
  => ~1.1 MiB/step, double-buffered ~2.2 MiB << 16 MiB VMEM.  All matmul
  dims are multiples of 128 (MXU-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import MASK_VARIANTS

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

PLANE = 32  # codes per bit-plane word (matches codec.PLANE_GROUP)


def _decode_codes(codes: jax.Array) -> jax.Array:
    """Table II: 3-bit code -> level value, as branch-free integer math.

    0->0, 1->+1, 2->+2, 3->+4, 4->-1, 5->-2, 6->-4, 7->0 (unused).
    """
    c = codes.astype(jnp.int32)
    pos = (c >= 1) & (c <= 3)
    neg = (c >= 4) & (c <= 6)
    # exponent: positive codes 1..3 -> 0..2; negative codes 4..6 -> 0..2
    exp = jnp.where(pos, c - 1, jnp.where(neg, c - 4, 0))
    mag = jnp.int32(1) << exp
    return jnp.where(pos, mag, jnp.where(neg, -mag, 0))


def _decode_codes_sm(codes: jax.Array) -> jax.Array:
    """Sign-magnitude (wire v2): bit 2 = sign, bits 1..0 = magnitude index.

    0->0, 1->+1, 2->+2, 3->+4, 4->-0 (=0), 5->-1, 6->-2, 7->-4.
    """
    c = codes.astype(jnp.int32)
    mag_idx = c & 3
    mag = jnp.int32(1) << jnp.maximum(mag_idx - 1, 0)
    val = jnp.where(mag_idx > 0, mag, 0)
    return jnp.where(c >= 4, -val, val)


def _decoder(sign_mag: bool):
    return _decode_codes_sm if sign_mag else _decode_codes


def _unpack_planes(planes_blk: jax.Array, bk: int, bn: int) -> jax.Array:
    """(bk//32, 3, bn) int32 interleaved bit-planes -> (bk, bn) int32 codes."""
    g = bk // PLANE
    # bit position j within each 32-code word, as an iota over a new axis
    j = jax.lax.broadcasted_iota(jnp.int32, (g, PLANE, bn), dimension=1)
    code = jnp.zeros((g, PLANE, bn), dtype=jnp.int32)
    for p in range(3):
        word = planes_blk[:, p, :]  # (g, bn)
        bit = (jax.lax.shift_right_logical(word[:, None, :], j)) & 1
        code = code | (bit << p)
    return code.reshape(bk, bn)


def _unpack_planes_major(
    planes_blk: jax.Array, bk: int, bn: int, n_planes: int
) -> jax.Array:
    """(n_planes, bk//32, bn) MSB-first plane-major words -> (bk, bn) codes.

    Streamed plane p carries code bit (2 - p); absent trailing planes
    contribute zero bits, exactly like a masked code stream.
    """
    g = bk // PLANE
    j = jax.lax.broadcasted_iota(jnp.int32, (g, PLANE, bn), dimension=1)
    code = jnp.zeros((g, PLANE, bn), dtype=jnp.int32)
    for p in range(n_planes):
        word = planes_blk[p]  # (g, bn)
        bit = (jax.lax.shift_right_logical(word[:, None, :], j)) & 1
        code = code | (bit << (2 - p))
    return code.reshape(bk, bn)


def _unpack(planes_blk, bk, bn, plane_major: bool, n_planes: int):
    if plane_major:
        return _unpack_planes_major(planes_blk, bk, bn, n_planes)
    return _unpack_planes(planes_blk, bk, bn)


def _planes_spec(plane_major: bool, n_planes: int, bk: int, bn: int):
    """Weight-plane BlockSpec for a (j-N, k-K) or (i-M, j-N, k-K) grid.

    Plane-major pins the plane axis at block row 0 with a block of only the
    demanded ``n_planes`` planes — the HBM read shortens with demand."""
    if plane_major:
        return (n_planes, bk // PLANE, bn), lambda *ids: (0, ids[-1], ids[-2])
    return (bk // PLANE, 3, bn), lambda *ids: (ids[-1], 0, ids[-2])


def _check_planes_shape(planes, kdim, n, plane_major):
    want = (3, kdim // PLANE, n) if plane_major else (kdim // PLANE, 3, n)
    if planes.shape != want:
        raise ValueError(f"planes shape {planes.shape} != {want}")


def _qsq_matmul_kernel(
    x_ref, planes_ref, scales_ref, o_ref, *,
    bk: int, group_size: int, sign_mag: bool, plane_major: bool, n_planes: int,
):
    bn = o_ref.shape[1]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = _unpack(planes_ref[...], bk, bn, plane_major, n_planes)
    levels = _decoder(sign_mag)(codes).astype(jnp.float32)   # (bk, bn)
    # broadcast per-group scales down each K-group of rows
    ng = bk // group_size
    lev_g = levels.reshape(ng, group_size, bn)
    w = (lev_g * scales_ref[...][:, None, :]).reshape(bk, bn)
    w = w.astype(x_ref.dtype)
    o_ref[...] += jnp.dot(
        x_ref[...], w, preferred_element_type=jnp.float32
    )


def _qsq_matmul_masked_kernel(
    xs_ref, planes_ref, scales_ref, o_ref, *,
    bk: int, group_size: int, sign_mag: bool, plane_major: bool,
    demand_drop: int,
):
    """Per-row plane-masked GEMM tile (see qsq_matvec._qsq_matvec_masked_kernel
    for the variant-split contract): one weight-tile stream, one static mask
    decode in VREGs per demanded variant, one dot per variant into the shared
    output.  ``demand_drop`` prunes the variants no live row can select."""
    bn = o_ref.shape[1]
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    codes = _unpack(planes_ref[...], bk, bn, plane_major, 3 - demand_drop)
    decode = _decoder(sign_mag)
    ng = bk // group_size
    sc = scales_ref[...]
    acc = None
    for i, mask in enumerate(MASK_VARIANTS[demand_drop:]):
        levels = decode(codes & mask).astype(jnp.float32)
        w = (levels.reshape(ng, group_size, bn) * sc[:, None, :]).reshape(bk, bn)
        d = jnp.dot(
            xs_ref[i], w.astype(xs_ref.dtype), preferred_element_type=jnp.float32
        )
        acc = d if acc is None else acc + d
    o_ref[...] += acc


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "bm", "bk", "bn", "interpret",
                     "sign_mag", "plane_major", "demand_drop"),
)
def qsq_matmul_masked(
    xs: jax.Array,
    planes: jax.Array,
    scales: jax.Array,
    *,
    group_size: int,
    bm: int = 256,
    bk: int = 512,
    bn: int = 256,
    interpret: bool = False,
    sign_mag: bool = False,
    plane_major: bool = False,
    demand_drop: int = 0,
) -> jax.Array:
    """Plane-masked sibling of :func:`qsq_matmul`:
    xs (3 - demand_drop, M, K) -> (M, N) f32.

    xs[i] holds the x rows whose plane mask is
    ``ref.MASK_VARIANTS[demand_drop + i]`` (other rows zero).  Same tiling
    contract as the unmasked kernel.  With ``plane_major`` the weight block
    only spans the ``3 - demand_drop`` demanded planes."""
    nv, m, kdim = xs.shape
    n = planes.shape[-1]
    if not 0 <= demand_drop <= 2:
        raise ValueError(f"demand_drop must be 0..2, got {demand_drop}")
    n_planes = 3 - demand_drop
    if nv != n_planes:
        raise ValueError(
            f"xs leading dim {nv} != {n_planes} demanded mask variants")
    _check_planes_shape(planes, kdim, n, plane_major)
    if scales.shape != (kdim // group_size, n):
        raise ValueError(f"scales shape {scales.shape} != {(kdim // group_size, n)}")
    bm, bk, bn = min(bm, m), min(bk, kdim), min(bn, n)
    if m % bm or kdim % bk or n % bn:
        raise ValueError(f"shape ({m},{kdim},{n}) not divisible by tile ({bm},{bk},{bn})")
    if bk % PLANE or bk % group_size:
        raise ValueError(f"bk={bk} must be a multiple of 32 and group_size={group_size}")

    grid = (m // bm, n // bn, kdim // bk)
    kernel = functools.partial(
        _qsq_matmul_masked_kernel, bk=bk, group_size=group_size,
        sign_mag=sign_mag, plane_major=plane_major, demand_drop=demand_drop)
    pshape, pmap = _planes_spec(plane_major, n_planes, bk, bn)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nv, bm, bk), lambda i, j, k: (0, i, k)),
            pl.BlockSpec(pshape, pmap),
            pl.BlockSpec((bk // group_size, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=_COMPILER_PARAMS(dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xs, planes, scales)


@functools.partial(
    jax.jit,
    static_argnames=("group_size", "bm", "bk", "bn", "interpret",
                     "sign_mag", "plane_major", "demand_drop"),
)
def qsq_matmul(
    x: jax.Array,
    planes: jax.Array,
    scales: jax.Array,
    *,
    group_size: int,
    bm: int = 256,
    bk: int = 512,
    bn: int = 256,
    interpret: bool = False,
    sign_mag: bool = False,
    plane_major: bool = False,
    demand_drop: int = 0,
) -> jax.Array:
    """Fused 3-bit dequant + matmul: x (M,K) @ decode(planes, scales) -> (M,N) f32."""
    m, kdim = x.shape
    n = planes.shape[-1]
    if not 0 <= demand_drop <= 2:
        raise ValueError(f"demand_drop must be 0..2, got {demand_drop}")
    if demand_drop and not plane_major:
        raise ValueError("demand_drop requires the plane-major layout")
    n_planes = 3 - demand_drop
    _check_planes_shape(planes, kdim, n, plane_major)
    if scales.shape != (kdim // group_size, n):
        raise ValueError(f"scales shape {scales.shape} != {(kdim // group_size, n)}")
    bm, bk, bn = min(bm, m), min(bk, kdim), min(bn, n)
    if m % bm or kdim % bk or n % bn:
        raise ValueError(f"shape ({m},{kdim},{n}) not divisible by tile ({bm},{bk},{bn})")
    if bk % PLANE or bk % group_size:
        raise ValueError(f"bk={bk} must be a multiple of 32 and group_size={group_size}")

    grid = (m // bm, n // bn, kdim // bk)
    kernel = functools.partial(
        _qsq_matmul_kernel, bk=bk, group_size=group_size,
        sign_mag=sign_mag, plane_major=plane_major, n_planes=n_planes)
    pshape, pmap = _planes_spec(plane_major, n_planes, bk, bn)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec(pshape, pmap),
            pl.BlockSpec((bk // group_size, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=_COMPILER_PARAMS(dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, planes, scales)

"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the kernels are validated against (interpret=True
on CPU, real compile on TPU).  They are deliberately written with the
simplest possible jnp — no tiling, no cleverness.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import codec
from repro.core.qsq import codes_to_levels, levels_to_codes


def qsq_dequant_ref(planes: jax.Array, scales: jax.Array, group_size: int) -> jax.Array:
    """Bit-plane packed codes + per-group scales -> dense f32 weights.

    planes: (K//32, 3, N) int32, scales: (K//G, N) f32 -> (K, N) f32.
    """
    codes = codec.unpack_bitplane(planes)  # (K, N) uint8
    levels = codes_to_levels(codes).astype(jnp.float32)  # (K, N)
    k = levels.shape[0]
    lev_g = levels.reshape(k // group_size, group_size, *levels.shape[1:])
    w = lev_g * scales[:, None]
    return w.reshape(levels.shape)


def qsq_matmul_ref(
    x: jax.Array, planes: jax.Array, scales: jax.Array, group_size: int
) -> jax.Array:
    """x (M,K) @ dequant(planes, scales) (K,N) -> (M,N) f32."""
    w = qsq_dequant_ref(planes, scales, group_size).astype(x.dtype)
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def qsq_quantize_ref(
    w: jax.Array, group_size: int, phi: int
) -> tuple[jax.Array, jax.Array]:
    """Nearest-level QSQ encode -> (codes (K,N) uint8, scales (K//G,N) f32).

    Matches repro.core.qsq.quantize(assign="nearest") exactly.
    """
    from repro.core.qsq import QSQConfig, quantize

    q = quantize(w, QSQConfig(phi=phi, group_size=group_size, assign="nearest"))
    return levels_to_codes(q.levels), q.scales

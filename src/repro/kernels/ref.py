"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the kernels are validated against (interpret=True
on CPU, real compile on TPU).  They are deliberately written with the
simplest possible jnp — no tiling, no cleverness.

Two code formats share the 3-bit planes: Table II offset codes (legacy,
``sign_mag=False``) and sign-magnitude codes (wire v2, ``sign_mag=True``).
Two physical layouts: plane-interleaved ``(K//32, 3, N)`` (legacy) and
plane-major ``(3, K//32, N)`` MSB-first, where a demand-dropped trailing
plane is simply never read (``demand_drop``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import codec
from repro.core.qsq import codes_to_levels, levels_to_codes, smcodes_to_levels

# The three plane masks a quality tier can put on a row: keep all 3 code
# planes, drop the LSB plane, drop the two LSB planes (drop = 0, 1, 2).
# Fixed and ordered, so masked kernels unroll over them statically — a
# per-row tier change is a data change, never a retrace.  Demand-driven
# dispatch restricts a call to the suffix ``MASK_VARIANTS[demand_drop:]``:
# with every live row at drop >= d, the first d variants are provably dead.
MASK_VARIANTS = (0b111, 0b110, 0b100)


def _unpack_codes(planes: jax.Array, plane_major: bool, n_planes: int = 3):
    """Planes in either layout -> (K, N) uint8 codes.

    For plane-major input only the leading ``n_planes`` planes are read —
    the XLA mirror of the shortened HBM stream.
    """
    if plane_major:
        return codec.unpack_bitplane_major(planes[:n_planes])
    return codec.unpack_bitplane(planes)


def _decode(codes: jax.Array, sign_mag: bool) -> jax.Array:
    return (smcodes_to_levels(codes) if sign_mag
            else codes_to_levels(codes)).astype(jnp.float32)


def qsq_dequant_ref(
    planes: jax.Array, scales: jax.Array, group_size: int, *,
    sign_mag: bool = False, plane_major: bool = False, n_planes: int = 3,
) -> jax.Array:
    """Bit-plane packed codes + per-group scales -> dense f32 weights.

    planes: (K//32, 3, N) int32 (or (3, K//32, N) plane-major),
    scales: (K//G, N) f32 -> (K, N) f32.
    """
    codes = _unpack_codes(planes, plane_major, n_planes)  # (K, N) uint8
    levels = _decode(codes, sign_mag)  # (K, N)
    k = levels.shape[0]
    lev_g = levels.reshape(k // group_size, group_size, *levels.shape[1:])
    w = lev_g * scales[:, None]
    return w.reshape(levels.shape)


def qsq_matmul_ref(
    x: jax.Array, planes: jax.Array, scales: jax.Array, group_size: int, *,
    sign_mag: bool = False, plane_major: bool = False, n_planes: int = 3,
) -> jax.Array:
    """x (M,K) @ dequant(planes, scales) (K,N) -> (M,N) f32."""
    w = qsq_dequant_ref(planes, scales, group_size, sign_mag=sign_mag,
                        plane_major=plane_major, n_planes=n_planes)
    return jnp.dot(x, w.astype(x.dtype), preferred_element_type=jnp.float32)


def qsq_dequant_masked_ref(
    planes: jax.Array, scales: jax.Array, group_size: int, code_mask: int, *,
    sign_mag: bool = False, plane_major: bool = False, n_planes: int = 3,
) -> jax.Array:
    """Dequant with ``code_mask`` ANDed onto every 3-bit code first.

    ``decode(codes & mask)`` on full-quality planes is bit-identical to a
    plain decode of planes whose dropped LSB words were zeroed
    (``PackedWeight.truncate``): zeroing a plane word and masking the
    corresponding code bit are the same operation on the code stream.
    """
    codes = _unpack_codes(planes, plane_major, n_planes)  # (K, N) uint8
    levels = _decode(codes & code_mask, sign_mag)
    k = levels.shape[0]
    lev_g = levels.reshape(k // group_size, group_size, *levels.shape[1:])
    w = lev_g * scales[:, None]
    return w.reshape(levels.shape)


def qsq_matmul_masked_ref(
    xs: jax.Array, planes: jax.Array, scales: jax.Array, group_size: int, *,
    sign_mag: bool = False, plane_major: bool = False, demand_drop: int = 0,
) -> jax.Array:
    """Per-row plane-masked matmul: xs (3 - demand_drop, M, K) -> (M, N) f32.

    ``xs[i]`` holds the rows of x whose plane mask is
    ``MASK_VARIANTS[demand_drop + i]`` (all other rows zeroed).  Each variant
    contracts against the weight decoded under that mask; a row's result is
    exactly its variant's term because the other variants contribute exact
    zeros — so row m equals ``x[m] @ dequant(truncate(drop_m))`` bit for bit.
    With ``demand_drop > 0`` on plane-major planes only ``3 - demand_drop``
    planes are ever unpacked: the demand-shortened read.
    """
    n_planes = 3 - demand_drop
    out = None
    for i, mask in enumerate(MASK_VARIANTS[demand_drop:]):
        w = qsq_dequant_masked_ref(
            planes, scales, group_size, mask, sign_mag=sign_mag,
            plane_major=plane_major, n_planes=n_planes)
        d = jnp.dot(xs[i], w.astype(xs.dtype), preferred_element_type=jnp.float32)
        out = d if out is None else out + d
    return out


def qsq_quantize_ref(
    w: jax.Array, group_size: int, phi: int
) -> tuple[jax.Array, jax.Array]:
    """Nearest-level QSQ encode -> (codes (K,N) uint8, scales (K//G,N) f32).

    Matches repro.core.qsq.quantize(assign="nearest") exactly.
    """
    from repro.core.qsq import QSQConfig, quantize

    q = quantize(w, QSQConfig(phi=phi, group_size=group_size, assign="nearest"))
    return levels_to_codes(q.levels), q.scales

"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the kernels are validated against (interpret=True
on CPU, real compile on TPU).  They are deliberately written with the
simplest possible jnp — no tiling, no cleverness.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import codec
from repro.core.qsq import codes_to_levels, levels_to_codes

# The three plane masks a quality tier can put on a row: keep all 3 code
# planes, drop the LSB plane, drop the two LSB planes (drop = 0, 1, 2).
# Fixed and ordered, so masked kernels unroll over them statically — a
# per-row tier change is a data change, never a retrace.
MASK_VARIANTS = (0b111, 0b110, 0b100)


def qsq_dequant_ref(planes: jax.Array, scales: jax.Array, group_size: int) -> jax.Array:
    """Bit-plane packed codes + per-group scales -> dense f32 weights.

    planes: (K//32, 3, N) int32, scales: (K//G, N) f32 -> (K, N) f32.
    """
    codes = codec.unpack_bitplane(planes)  # (K, N) uint8
    levels = codes_to_levels(codes).astype(jnp.float32)  # (K, N)
    k = levels.shape[0]
    lev_g = levels.reshape(k // group_size, group_size, *levels.shape[1:])
    w = lev_g * scales[:, None]
    return w.reshape(levels.shape)


def qsq_matmul_ref(
    x: jax.Array, planes: jax.Array, scales: jax.Array, group_size: int
) -> jax.Array:
    """x (M,K) @ dequant(planes, scales) (K,N) -> (M,N) f32."""
    w = qsq_dequant_ref(planes, scales, group_size).astype(x.dtype)
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def qsq_dequant_masked_ref(
    planes: jax.Array, scales: jax.Array, group_size: int, code_mask: int
) -> jax.Array:
    """Dequant with ``code_mask`` ANDed onto every 3-bit code first.

    ``decode(codes & mask)`` on full-quality planes is bit-identical to a
    plain decode of planes whose dropped LSB words were zeroed
    (``PackedWeight.truncate``): zeroing a plane word and masking the
    corresponding code bit are the same operation on the code stream.
    """
    codes = codec.unpack_bitplane(planes)  # (K, N) uint8
    levels = codes_to_levels(codes & code_mask).astype(jnp.float32)
    k = levels.shape[0]
    lev_g = levels.reshape(k // group_size, group_size, *levels.shape[1:])
    w = lev_g * scales[:, None]
    return w.reshape(levels.shape)


def qsq_matmul_masked_ref(
    xs: jax.Array, planes: jax.Array, scales: jax.Array, group_size: int
) -> jax.Array:
    """Per-row plane-masked matmul: xs (3, M, K) -> (M, N) f32.

    ``xs[i]`` holds the rows of x whose plane mask is ``MASK_VARIANTS[i]``
    (all other rows zeroed).  Each variant contracts against the weight
    decoded under that mask; a row's result is exactly its variant's term
    because the other variants contribute exact zeros — so row m equals
    ``x[m] @ dequant(truncate(drop_m))`` bit for bit.
    """
    out = None
    for i, mask in enumerate(MASK_VARIANTS):
        w = qsq_dequant_masked_ref(planes, scales, group_size, mask)
        d = jnp.dot(xs[i], w.astype(xs.dtype), preferred_element_type=jnp.float32)
        out = d if out is None else out + d
    return out


def qsq_quantize_ref(
    w: jax.Array, group_size: int, phi: int
) -> tuple[jax.Array, jax.Array]:
    """Nearest-level QSQ encode -> (codes (K,N) uint8, scales (K//G,N) f32).

    Matches repro.core.qsq.quantize(assign="nearest") exactly.
    """
    from repro.core.qsq import QSQConfig, quantize

    q = quantize(w, QSQConfig(phi=phi, group_size=group_size, assign="nearest"))
    return levels_to_codes(q.levels), q.scales

"""Pallas TPU kernel: fused QSQ dequant + small-M matmul (decode GEMV).

``qsq_matmul`` tiles all three dims for the MXU, which is right for
prefill/train GEMMs but wasteful at decode shapes: with M = 8 batch slots a
256-row M tile is 97% padding, and the (i, j, k) grid re-reads the output
block every K step.  This kernel is the GEMV specialization the dispatcher
(`kernels/dispatch.py`) routes small-M matmuls to:

* the whole (small) M extent lives in one block — no M grid dim, no M
  padding beyond the 8-row sublane;
* the grid is (N, K) with K innermost ("arbitrary"), accumulating into a
  **VMEM scratch accumulator** that is written back to the output exactly
  once, on the last K step — the output block is never re-streamed;
* scales are folded into the plane unpack (one multiply on the decoded
  levels while they are still in VREGs), so the weight tile goes bits ->
  levels -> scaled f32 without a dense round-trip;
* tiles default to GEMV proportions (deep K, modest N) instead of the
  square 256x512x256 GEMM config — the weight stream, not the MXU, is the
  roofline term at M <= 16.

Layout matches qsq_matmul: x (M, K), planes (K//32, 3, N) int32 (or
(3, K//32, N) when ``plane_major``), scales (K//G, N) f32 -> out (M, N)
f32.  ``sign_mag``/``plane_major``/``demand_drop`` follow the qsq_matmul
contract; since decode is weight-stream bound, demand-shortened plane-major
reads cut the dominant roofline term almost linearly in planes demanded.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.qsq_matmul import (
    _COMPILER_PARAMS,
    PLANE,
    _check_planes_shape,
    _decoder,
    _planes_spec,
    _unpack,
)
from repro.kernels.ref import MASK_VARIANTS


def _qsq_matvec_kernel(
    x_ref, planes_ref, scales_ref, o_ref, acc_ref, *,
    bk: int, group_size: int, nk: int, sign_mag: bool, plane_major: bool,
    n_planes: int,
):
    bn = o_ref.shape[1]
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = _unpack(planes_ref[...], bk, bn, plane_major, n_planes)
    # scales folded into the unpack: levels scale while still in VREGs
    levels = _decoder(sign_mag)(codes).astype(jnp.float32)
    ng = bk // group_size
    w = (levels.reshape(ng, group_size, bn)
         * scales_ref[...][:, None, :]).reshape(bk, bn)
    acc_ref[...] += jnp.dot(
        x_ref[...], w.astype(x_ref.dtype), preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def _qsq_matvec_masked_kernel(
    xs_ref, planes_ref, scales_ref, o_ref, acc_ref, *,
    bk: int, group_size: int, nk: int, sign_mag: bool, plane_major: bool,
    demand_drop: int,
):
    """Per-row plane-masked GEMV: xs_ref (3 - demand_drop, M, bk) carries x
    pre-split by mask variant (rows of other variants zeroed).  The weight
    tile streams ONCE; it is decoded under each demanded static plane mask
    in VREGs (``codes & mask`` — a dropped plane is a masked term of the
    unpack) and each variant contracts its own x rows.  A row's accumulator
    only ever receives its variant's product plus exact zeros, so per-row
    output is bit-identical to the unmasked kernel on plane-truncated
    weights.  ``demand_drop`` prunes variants no live row selects; with
    ``plane_major`` the streamed weight block also shrinks to the demanded
    planes."""
    bn = o_ref.shape[1]
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = _unpack(planes_ref[...], bk, bn, plane_major, 3 - demand_drop)
    decode = _decoder(sign_mag)
    ng = bk // group_size
    sc = scales_ref[...]
    acc = None
    for i, mask in enumerate(MASK_VARIANTS[demand_drop:]):
        levels = decode(codes & mask).astype(jnp.float32)
        w = (levels.reshape(ng, group_size, bn) * sc[:, None, :]).reshape(bk, bn)
        d = jnp.dot(
            xs_ref[i], w.astype(xs_ref.dtype), preferred_element_type=jnp.float32
        )
        acc = d if acc is None else acc + d
    acc_ref[...] += acc

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("group_size", "bk", "bn", "interpret",
                              "sign_mag", "plane_major", "demand_drop")
)
def qsq_matvec_masked(
    xs: jax.Array,
    planes: jax.Array,
    scales: jax.Array,
    *,
    group_size: int,
    bk: int = 1024,
    bn: int = 256,
    interpret: bool = False,
    sign_mag: bool = False,
    plane_major: bool = False,
    demand_drop: int = 0,
) -> jax.Array:
    """Plane-masked sibling of :func:`qsq_matvec`:
    xs (3 - demand_drop, M, K) -> (M, N).

    xs[i] holds the x rows whose plane mask is
    ``MASK_VARIANTS[demand_drop + i]`` (other rows zero); the dispatcher
    builds it from the per-row plane_mask operand.  Same tiling contract as
    the unmasked kernel."""
    nv, m, kdim = xs.shape
    n = planes.shape[-1]
    if not 0 <= demand_drop <= 2:
        raise ValueError(f"demand_drop must be 0..2, got {demand_drop}")
    n_planes = 3 - demand_drop
    if nv != n_planes:
        raise ValueError(
            f"xs leading dim {nv} != {n_planes} demanded mask variants")
    _check_planes_shape(planes, kdim, n, plane_major)
    if scales.shape != (kdim // group_size, n):
        raise ValueError(f"scales shape {scales.shape} != {(kdim // group_size, n)}")
    bk, bn = min(bk, kdim), min(bn, n)
    if kdim % bk or n % bn:
        raise ValueError(f"shape ({m},{kdim},{n}) not divisible by tile (bk={bk},bn={bn})")
    if bk % PLANE or bk % group_size:
        raise ValueError(f"bk={bk} must be a multiple of 32 and group_size={group_size}")

    nk = kdim // bk
    grid = (n // bn, nk)
    kernel = functools.partial(
        _qsq_matvec_masked_kernel, bk=bk, group_size=group_size, nk=nk,
        sign_mag=sign_mag, plane_major=plane_major, demand_drop=demand_drop
    )
    pshape, pmap = _planes_spec(plane_major, n_planes, bk, bn)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((nv, m, bk), lambda j, k: (0, 0, k)),
            pl.BlockSpec(pshape, pmap),
            pl.BlockSpec((bk // group_size, bn), lambda j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m, bn), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xs, planes, scales)


@functools.partial(
    jax.jit, static_argnames=("group_size", "bk", "bn", "interpret",
                              "sign_mag", "plane_major", "demand_drop")
)
def qsq_matvec(
    x: jax.Array,
    planes: jax.Array,
    scales: jax.Array,
    *,
    group_size: int,
    bk: int = 1024,
    bn: int = 256,
    interpret: bool = False,
    sign_mag: bool = False,
    plane_major: bool = False,
    demand_drop: int = 0,
) -> jax.Array:
    """Small-M fused 3-bit dequant matmul: x (M,K) @ decode(planes, scales).

    The full M extent is one block; callers (the dispatcher) keep M small
    (decode shapes) and pad/tile K, N so ``bk | K`` and ``bn | N``.
    """
    m, kdim = x.shape
    n = planes.shape[-1]
    if not 0 <= demand_drop <= 2:
        raise ValueError(f"demand_drop must be 0..2, got {demand_drop}")
    if demand_drop and not plane_major:
        raise ValueError("demand_drop requires the plane-major layout")
    n_planes = 3 - demand_drop
    _check_planes_shape(planes, kdim, n, plane_major)
    if scales.shape != (kdim // group_size, n):
        raise ValueError(f"scales shape {scales.shape} != {(kdim // group_size, n)}")
    bk, bn = min(bk, kdim), min(bn, n)
    if kdim % bk or n % bn:
        raise ValueError(f"shape ({m},{kdim},{n}) not divisible by tile (bk={bk},bn={bn})")
    if bk % PLANE or bk % group_size:
        raise ValueError(f"bk={bk} must be a multiple of 32 and group_size={group_size}")

    nk = kdim // bk
    grid = (n // bn, nk)
    kernel = functools.partial(
        _qsq_matvec_kernel, bk=bk, group_size=group_size, nk=nk,
        sign_mag=sign_mag, plane_major=plane_major, n_planes=n_planes
    )
    pshape, pmap = _planes_spec(plane_major, n_planes, bk, bn)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, bk), lambda j, k: (0, k)),
            pl.BlockSpec(pshape, pmap),
            pl.BlockSpec((bk // group_size, bn), lambda j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m, bn), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, planes, scales)

"""Pallas TPU kernel: fused QSQ dequant + small-M matmul (decode GEMV).

``qsq_matmul`` tiles all three dims for the MXU, which is right for
prefill/train GEMMs but wasteful at decode shapes: with M = 8 batch slots a
256-row M tile is 97% padding, and the (i, j, k) grid re-reads the output
block every K step.  This kernel is the GEMV specialization the dispatcher
(`kernels/dispatch.py`) routes small-M matmuls to:

* the whole (small) M extent lives in one block — no M grid dim, no M
  padding beyond the 8-row sublane;
* the grid is (N, K) with K innermost ("arbitrary"), accumulating into a
  **VMEM scratch accumulator** that is written back to the output exactly
  once, on the last K step — the output block is never re-streamed;
* scales are folded into the plane unpack (one multiply on the decoded
  levels while they are still in VREGs), so the weight tile goes bits ->
  levels -> scaled f32 without a dense round-trip;
* tiles default to GEMV proportions (deep K, modest N) instead of the
  square 256x512x256 GEMM config — the weight stream, not the MXU, is the
  roofline term at M <= 16.

Layout matches qsq_matmul: x (M, K), planes (K//32, 3, N) int32,
scales (K//G, N) f32 -> out (M, N) f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.qsq_matmul import (
    _COMPILER_PARAMS, PLANE, _decode_codes, _unpack_planes,
)
from repro.kernels.ref import MASK_VARIANTS


def _qsq_matvec_kernel(
    x_ref, planes_ref, scales_ref, o_ref, acc_ref, *, bk: int, group_size: int, nk: int
):
    bn = o_ref.shape[1]
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = _unpack_planes(planes_ref[...], bk, bn)           # (bk, bn) int32
    # scales folded into the unpack: levels scale while still in VREGs
    levels = _decode_codes(codes).astype(jnp.float32)
    ng = bk // group_size
    w = (levels.reshape(ng, group_size, bn)
         * scales_ref[...][:, None, :]).reshape(bk, bn)
    acc_ref[...] += jnp.dot(
        x_ref[...], w.astype(x_ref.dtype), preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


def _qsq_matvec_masked_kernel(
    xs_ref, planes_ref, scales_ref, o_ref, acc_ref, *, bk: int, group_size: int, nk: int
):
    """Per-row plane-masked GEMV: xs_ref (3, M, bk) carries x pre-split by
    mask variant (rows of other variants zeroed).  The weight tile streams
    ONCE; it is decoded under each of the three static plane masks in VREGs
    (``codes & mask`` — a dropped plane is a masked term of the unpack) and
    each variant contracts its own x rows.  A row's accumulator only ever
    receives its variant's product plus exact zeros, so per-row output is
    bit-identical to the unmasked kernel on plane-truncated weights."""
    bn = o_ref.shape[1]
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = _unpack_planes(planes_ref[...], bk, bn)           # (bk, bn) int32
    ng = bk // group_size
    sc = scales_ref[...]
    acc = None
    for i, mask in enumerate(MASK_VARIANTS):
        levels = _decode_codes(codes & mask).astype(jnp.float32)
        w = (levels.reshape(ng, group_size, bn) * sc[:, None, :]).reshape(bk, bn)
        d = jnp.dot(
            xs_ref[i], w.astype(xs_ref.dtype), preferred_element_type=jnp.float32
        )
        acc = d if acc is None else acc + d
    acc_ref[...] += acc

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("group_size", "bk", "bn", "interpret")
)
def qsq_matvec_masked(
    xs: jax.Array,
    planes: jax.Array,
    scales: jax.Array,
    *,
    group_size: int,
    bk: int = 1024,
    bn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Plane-masked sibling of :func:`qsq_matvec`: xs (3, M, K) -> (M, N).

    xs[i] holds the x rows whose plane mask is ``MASK_VARIANTS[i]`` (other
    rows zero); the dispatcher builds it from the per-row plane_mask
    operand.  Same tiling contract as the unmasked kernel."""
    nv, m, kdim = xs.shape
    n = planes.shape[-1]
    if nv != len(MASK_VARIANTS):
        raise ValueError(f"xs leading dim {nv} != {len(MASK_VARIANTS)} mask variants")
    if planes.shape != (kdim // PLANE, 3, n):
        raise ValueError(f"planes shape {planes.shape} != {(kdim // PLANE, 3, n)}")
    if scales.shape != (kdim // group_size, n):
        raise ValueError(f"scales shape {scales.shape} != {(kdim // group_size, n)}")
    bk, bn = min(bk, kdim), min(bn, n)
    if kdim % bk or n % bn:
        raise ValueError(f"shape ({m},{kdim},{n}) not divisible by tile (bk={bk},bn={bn})")
    if bk % PLANE or bk % group_size:
        raise ValueError(f"bk={bk} must be a multiple of 32 and group_size={group_size}")

    nk = kdim // bk
    grid = (n // bn, nk)
    kernel = functools.partial(
        _qsq_matvec_masked_kernel, bk=bk, group_size=group_size, nk=nk
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((len(MASK_VARIANTS), m, bk), lambda j, k: (0, 0, k)),
            pl.BlockSpec((bk // PLANE, 3, bn), lambda j, k: (k, 0, j)),
            pl.BlockSpec((bk // group_size, bn), lambda j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m, bn), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(xs, planes, scales)


@functools.partial(
    jax.jit, static_argnames=("group_size", "bk", "bn", "interpret")
)
def qsq_matvec(
    x: jax.Array,
    planes: jax.Array,
    scales: jax.Array,
    *,
    group_size: int,
    bk: int = 1024,
    bn: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Small-M fused 3-bit dequant matmul: x (M,K) @ decode(planes, scales).

    The full M extent is one block; callers (the dispatcher) keep M small
    (decode shapes) and pad/tile K, N so ``bk | K`` and ``bn | N``.
    """
    m, kdim = x.shape
    n = planes.shape[-1]
    if planes.shape != (kdim // PLANE, 3, n):
        raise ValueError(f"planes shape {planes.shape} != {(kdim // PLANE, 3, n)}")
    if scales.shape != (kdim // group_size, n):
        raise ValueError(f"scales shape {scales.shape} != {(kdim // group_size, n)}")
    bk, bn = min(bk, kdim), min(bn, n)
    if kdim % bk or n % bn:
        raise ValueError(f"shape ({m},{kdim},{n}) not divisible by tile (bk={bk},bn={bn})")
    if bk % PLANE or bk % group_size:
        raise ValueError(f"bk={bk} must be a multiple of 32 and group_size={group_size}")

    nk = kdim // bk
    grid = (n // bn, nk)
    kernel = functools.partial(
        _qsq_matvec_kernel, bk=bk, group_size=group_size, nk=nk
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, bk), lambda j, k: (0, k)),
            pl.BlockSpec((bk // PLANE, 3, bn), lambda j, k: (k, 0, j)),
            pl.BlockSpec((bk // group_size, bn), lambda j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((m, bn), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m, bn), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, planes, scales)

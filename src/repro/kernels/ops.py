"""Public jit'd entry points for the Pallas kernels.

On CPU (this container) the kernels run in interpret mode; on TPU they
compile to Mosaic.  ``auto_interpret()`` picks per-backend so the same code
path works in tests, benchmarks and the real launcher.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import codec
from repro.kernels import ref
from repro.kernels.qsq_matmul import qsq_matmul as _qsq_matmul_pallas
from repro.kernels.qsq_matmul import qsq_matmul_masked as _qsq_matmul_masked_pallas
from repro.kernels.qsq_matvec import qsq_matvec as _qsq_matvec_pallas
from repro.kernels.qsq_matvec import qsq_matvec_masked as _qsq_matvec_masked_pallas
from repro.kernels.qsq_quantize import qsq_quantize as _qsq_quantize_pallas


def auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def qsq_matmul(
    x: jax.Array,
    planes: jax.Array,
    scales: jax.Array,
    *,
    group_size: int,
    bm: int = 256,
    bk: int = 512,
    bn: int = 256,
    interpret: bool | None = None,
    use_pallas: bool = True,
    sign_mag: bool = False,
    plane_major: bool = False,
    demand_drop: int = 0,
) -> jax.Array:
    """x @ dequant(planes, scales).  Falls back to the XLA ref when asked."""
    if not use_pallas:
        return ref.qsq_matmul_ref(x, planes, scales, group_size,
                                  sign_mag=sign_mag, plane_major=plane_major,
                                  n_planes=3 - demand_drop)
    if interpret is None:
        interpret = auto_interpret()
    return _qsq_matmul_pallas(
        x, planes, scales, group_size=group_size, bm=bm, bk=bk, bn=bn,
        interpret=interpret, sign_mag=sign_mag, plane_major=plane_major,
        demand_drop=demand_drop,
    )


def qsq_matvec(
    x: jax.Array,
    planes: jax.Array,
    scales: jax.Array,
    *,
    group_size: int,
    bk: int = 1024,
    bn: int = 256,
    interpret: bool | None = None,
    use_pallas: bool = True,
    sign_mag: bool = False,
    plane_major: bool = False,
    demand_drop: int = 0,
) -> jax.Array:
    """Small-M x @ dequant(planes, scales) — the decode-shape GEMV kernel."""
    if not use_pallas:
        return ref.qsq_matmul_ref(x, planes, scales, group_size,
                                  sign_mag=sign_mag, plane_major=plane_major,
                                  n_planes=3 - demand_drop)
    if interpret is None:
        interpret = auto_interpret()
    return _qsq_matvec_pallas(
        x, planes, scales, group_size=group_size, bk=bk, bn=bn,
        interpret=interpret, sign_mag=sign_mag, plane_major=plane_major,
        demand_drop=demand_drop,
    )


def qsq_matmul_masked(
    xs: jax.Array,
    planes: jax.Array,
    scales: jax.Array,
    *,
    group_size: int,
    bm: int = 256,
    bk: int = 512,
    bn: int = 256,
    interpret: bool | None = None,
    use_pallas: bool = True,
    sign_mag: bool = False,
    plane_major: bool = False,
    demand_drop: int = 0,
) -> jax.Array:
    """Per-row plane-masked GEMM: xs (3 - demand_drop, M, K) variant-split
    activations."""
    if not use_pallas:
        return ref.qsq_matmul_masked_ref(xs, planes, scales, group_size,
                                         sign_mag=sign_mag,
                                         plane_major=plane_major,
                                         demand_drop=demand_drop)
    if interpret is None:
        interpret = auto_interpret()
    return _qsq_matmul_masked_pallas(
        xs, planes, scales, group_size=group_size, bm=bm, bk=bk, bn=bn,
        interpret=interpret, sign_mag=sign_mag, plane_major=plane_major,
        demand_drop=demand_drop,
    )


def qsq_matvec_masked(
    xs: jax.Array,
    planes: jax.Array,
    scales: jax.Array,
    *,
    group_size: int,
    bk: int = 1024,
    bn: int = 256,
    interpret: bool | None = None,
    use_pallas: bool = True,
    sign_mag: bool = False,
    plane_major: bool = False,
    demand_drop: int = 0,
) -> jax.Array:
    """Per-row plane-masked GEMV: xs (3 - demand_drop, M, K) variant-split
    activations."""
    if not use_pallas:
        return ref.qsq_matmul_masked_ref(xs, planes, scales, group_size,
                                         sign_mag=sign_mag,
                                         plane_major=plane_major,
                                         demand_drop=demand_drop)
    if interpret is None:
        interpret = auto_interpret()
    return _qsq_matvec_masked_pallas(
        xs, planes, scales, group_size=group_size, bk=bk, bn=bn,
        interpret=interpret, sign_mag=sign_mag, plane_major=plane_major,
        demand_drop=demand_drop,
    )


def qsq_quantize(
    w: jax.Array,
    *,
    group_size: int,
    phi: int = 4,
    interpret: bool | None = None,
    use_pallas: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Encode a (K, N) tensor -> (codes uint8 (K,N), scales (K//G, N))."""
    if not use_pallas:
        return ref.qsq_quantize_ref(w, group_size, phi)
    if interpret is None:
        interpret = auto_interpret()
    codes_i32, scales = _qsq_quantize_pallas(
        w, group_size=group_size, phi=phi, interpret=interpret
    )
    return codes_i32.astype(jnp.uint8), scales


def pack_weight(w: jax.Array, *, group_size: int, phi: int = 4, **kw):
    """One-call helper: dense weight -> (bit-planes, scales) for qsq_matmul."""
    codes, scales = qsq_quantize(w, group_size=group_size, phi=phi, **kw)
    return codec.pack_bitplane(codes), scales

"""Pallas TPU kernels for the QSQ hot spots.

qsq_matmul   — fused 3-bit dequant + matmul (the Table-II decoder on-chip)
qsq_quantize — Eq. 9 + nearest-level encode (checkpoint/grad compression)

Each has a pure-jnp oracle in ref.py; tests sweep shapes/dtypes with
interpret=True and assert_allclose against the oracle.
"""
from repro.kernels.ops import qsq_matmul, qsq_quantize, pack_weight, auto_interpret
from repro.kernels import ref

__all__ = ["qsq_matmul", "qsq_quantize", "pack_weight", "auto_interpret", "ref"]

"""Pallas TPU kernels for the QSQ hot spots.

qsq_matmul   — fused 3-bit dequant + matmul (the Table-II decoder on-chip)
qsq_matvec   — small-M (decode-shape) GEMV specialization of the above
qsq_quantize — Eq. 9 + nearest-level encode (checkpoint/grad compression)
dispatch     — shape-aware routing between the kernels and the XLA ref,
               with tile padding for ragged shapes and a tuned-tile table
               (benchmarks/autotune.py writes it)

Each kernel has a pure-jnp oracle in ref.py; tests sweep shapes/dtypes with
interpret=True and assert_allclose against the oracle.
"""
from repro.kernels import ref
from repro.kernels.ops import auto_interpret, pack_weight, qsq_matmul, qsq_matvec, qsq_quantize

__all__ = [
    "qsq_matmul", "qsq_matvec", "qsq_quantize", "pack_weight",
    "auto_interpret", "ref",
]

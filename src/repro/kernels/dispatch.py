"""Shape-aware kernel dispatch for the packed QSQ matmul.

Every ``PackedWeight.matmul`` lands here.  The dispatcher keys on
(M, K, N, G, backend) and routes to the best available path:

* ``pallas_gemv`` — the small-M decode kernel (`qsq_matvec.py`): one M
  block, VMEM scratch accumulator, GEMV-proportioned tiles;
* ``pallas_gemm`` — the tiled MXU kernel (`qsq_matmul.py`) for prefill /
  train shapes;
* ``xla_ref``     — the pure-XLA reference (`ref.qsq_matmul_ref`), used
  when the kernel switch (`quant.store.set_packed_matmul_kernel(False)`)
  is off.  It still consumes the packed representation — there is no
  dense-weight fallback path anywhere in dispatch.

Shapes that don't divide the chosen tile are **zero-padded** to it (M up
to the sublane, N up to the lane/tile, K never — K is always a common
multiple of the 32-code plane word and the scale group, so an exact
K tile always exists).  Zero x rows and zero plane words contribute
exact zeros, so padding changes no output value; the pad is sliced off
after the kernel.  This eliminates the old behaviour where a tile-ragged
shape silently materialized the whole dense weight inside jit.

Tile configs resolve, in order, from:
1. an exact (backend, M, K, N, G) entry in the tuned table,
2. the backend's shape-class default ("gemv" / "gemm") in the table,
3. built-in heuristics.

The tuned table is a checked-in JSON (`kernels/tuned_tiles.json`) written
by ``benchmarks/autotune.py``; point ``REPRO_TUNED_TABLE`` at another file
(or call :func:`set_tuned_table`) for a data-driven override.

Dispatch decisions are counted in :data:`counters` (trace-time, keyed by
route and ``route:padded|exact``) so tests and benchmarks can assert which
path a shape took.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import json
import math
import os
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.core import codec
from repro.kernels import ref
from repro.kernels.ref import MASK_VARIANTS

PLANE = codec.PLANE_GROUP

# M at or below this routes to the GEMV kernel (decode shapes: batch slots
# x one token).  Above it the MXU GEMM tiling wins.
GEMV_M_MAX = 16

# TPU register tiling: f32 sublane x lane.  Padded tiles honor these so a
# plan that validates in interpret mode is also Mosaic-legal.
SUBLANE = 8
LANE = 128

ROUTE_GEMV = "pallas_gemv"
ROUTE_GEMM = "pallas_gemm"
ROUTE_XLA = "xla_ref"

DEFAULT_TABLE_PATH = Path(__file__).parent / "tuned_tiles.json"
TABLE_ENV = "REPRO_TUNED_TABLE"

# trace-time dispatch counters: route name, plus "<route>:padded|exact"
counters: collections.Counter = collections.Counter()

# trace-time plane-traffic accounting, kept separate from the route
# counters so route assertions stay stable.  Per packed_matmul trace:
#   "<route>:planes<P>"   — calls that streamed P of the 3 bit-planes
#   "plane_reads"         — plane-tiles streamed (planes touched x tiles)
#   "plane_words_read"    — int32 plane words the routed kernel streams
#   "plane_words_full"    — words a full 3-plane stream would have read
# read/full < 1 is exactly the demand-driven HBM saving on that trace.
traffic: collections.Counter = collections.Counter()


# serving-phase label for traffic attribution ("" = unlabeled).  Set only
# via dispatch_phase(); counters/traffic stay private to this module.
_phase: str = ""


def reset_counters() -> None:
    counters.clear()
    traffic.clear()


@contextlib.contextmanager
def dispatch_phase(label: str):
    """Attribute plane traffic traced inside the block to a serving phase.

    The serving engine wraps its speculative draft ticks and verify
    dispatches in ``dispatch_phase("draft")`` / ``dispatch_phase("verify")``
    so :data:`traffic` splits plane reads by phase under extra
    ``"phase:<label>:plane_words_read|full"`` keys.  Like every counter
    here these move at TRACE time only — they record what each compiled
    program streams per call, labeled by the phase that first compiled
    it — so cached dispatches (and ``no_retrace`` blocks) never see them
    drift."""
    global _phase
    prev = _phase
    _phase = str(label)
    try:
        yield
    finally:
        _phase = prev


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """One kernel tiling: which kernel, and its (bm, bk, bn) preferences."""

    kind: str  # "gemv" | "gemm"
    bm: int
    bk: int
    bn: int

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Plan:
    """A resolved dispatch: route + fitted tiles + padded problem shape."""

    route: str
    m: int
    k: int
    n: int
    pm: int  # padded M (== m when exact)
    pn: int  # padded N
    bm: int = 0
    bk: int = 0
    bn: int = 0

    @property
    def padded(self) -> bool:
        return (self.pm, self.pn) != (self.m, self.n)


# --------------------------------------------------------------------------
# Tuned-table IO
# --------------------------------------------------------------------------
_BUILTIN_CLASS_DEFAULTS = {
    "gemv": TileConfig(kind="gemv", bm=SUBLANE, bk=1024, bn=256),
    "gemm": TileConfig(kind="gemm", bm=256, bk=512, bn=256),
}

_TABLE: dict | None = None


def shape_key(m: int, k: int, n: int, g: int) -> str:
    return f"{m}x{k}x{n}g{g}"


def shape_class(m: int) -> str:
    return "gemv" if m <= GEMV_M_MAX else "gemm"


def load_tuned_table(path: str | Path | None = None) -> dict:
    """Read a dispatch table JSON: {backend: {key: {kind, bm, bk, bn}}}."""
    path = Path(path or os.environ.get(TABLE_ENV) or DEFAULT_TABLE_PATH)
    with open(path) as f:
        table = json.load(f)
    table.pop("version", None)
    return table


def save_tuned_table(table: dict, path: str | Path) -> Path:
    """Write a dispatch table JSON (inverse of :func:`load_tuned_table`)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    out = {"version": 1}
    for backend, entries in table.items():
        out[backend] = {
            key: cfg.to_json() if isinstance(cfg, TileConfig) else dict(cfg)
            for key, cfg in entries.items()
        }
    with open(path, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def set_tuned_table(table: dict | str | Path | None) -> None:
    """Install a table override (dict or path); None re-reads the default."""
    global _TABLE
    if table is None:
        _TABLE = None
        return
    if isinstance(table, (str, Path)):
        table = load_tuned_table(table)
    _TABLE = dict(table)


def _table() -> dict:
    global _TABLE
    if _TABLE is None:
        try:
            _TABLE = load_tuned_table()
        except (OSError, json.JSONDecodeError):
            if os.environ.get(TABLE_ENV):
                # an explicit override that doesn't load is a config error,
                # not something to silently paper over with builtin tiles
                raise
            _TABLE = {}
    return _TABLE


def _resolve_config(m: int, k: int, n: int, g: int, backend: str) -> TileConfig:
    """(shape, backend) -> preferred TileConfig, deterministically."""
    entries = _table().get(backend, {})
    raw = entries.get(shape_key(m, k, n, g)) or entries.get(shape_class(m))
    if raw is not None:
        cfg = raw if isinstance(raw, TileConfig) else TileConfig(**raw)
    else:
        cfg = _BUILTIN_CLASS_DEFAULTS[shape_class(m)]
    if cfg.kind == "gemv" and m > GEMV_M_MAX:
        # a table can promote small-M shapes to GEMM, never the reverse:
        # the GEMV kernel keeps all of M in one block.
        cfg = dataclasses.replace(cfg, kind="gemm")
    return cfg


# --------------------------------------------------------------------------
# Tile fitting (with padding for ragged shapes)
# --------------------------------------------------------------------------
def _fit_dim(dim: int, pref: int, align: int) -> tuple[int, int]:
    """Fit a tile to ``dim``: returns (tile, padded_dim) with tile | padded.

    A dim at most ``pref`` is one whole block (no padding; a single
    unaligned block is masked by Mosaic).  Larger dims prefer an exact
    ``align``-multiple divisor (no padding); failing that, the
    ``align``-multiple tile at most ``pref`` that minimizes zero padding
    (ties to the larger tile), with ``dim`` padded up to it.
    """
    pref = max(pref, align)
    if dim <= pref:
        return dim, dim
    for t in range(pref, 0, -1):
        if dim % t == 0 and t % align == 0:
            return t, dim
    cands = range(align, pref + 1, align)
    tile = min(cands, key=lambda t: (-(-dim // t) * t, -t))
    return tile, -(-dim // tile) * tile


def _fit_k(k: int, pref: int, g: int) -> int:
    """K tile: largest divisor of K <= pref that the plane word (32) and the
    scale group both divide.  Always exists — K is a common multiple of 32
    and G, hence of lcm(32, G) — so K is never padded (padding K would also
    mean fabricating scale rows)."""
    mult = (PLANE * g) // math.gcd(PLANE, g)
    for t in range(min(pref, k), 0, -1):
        if k % t == 0 and t % mult == 0:
            return t
    return mult  # mult divides k by construction


def plan(m: int, k: int, n: int, g: int, *, backend: str | None = None,
         use_kernel: bool = True) -> Plan:
    """Resolve (M, K, N, G, backend) to a concrete kernel plan."""
    if k % PLANE:
        raise ValueError(f"K={k} is not a multiple of the {PLANE}-code plane word")
    if k % g:
        raise ValueError(f"group_size={g} does not divide K={k}")
    if not use_kernel:
        return Plan(route=ROUTE_XLA, m=m, k=k, n=n, pm=m, pn=n)
    backend = backend or jax.default_backend()
    cfg = _resolve_config(m, k, n, g, backend)
    bk = _fit_k(k, cfg.bk, g)
    if cfg.kind == "gemv":
        pm = m if m % SUBLANE == 0 or m < SUBLANE else -(-m // SUBLANE) * SUBLANE
        bn, pn = _fit_dim(n, cfg.bn, LANE)
        return Plan(route=ROUTE_GEMV, m=m, k=k, n=n, pm=pm, pn=pn,
                    bm=pm, bk=bk, bn=bn)
    bm, pm = _fit_dim(m, cfg.bm, SUBLANE)
    bn, pn = _fit_dim(n, cfg.bn, LANE)
    return Plan(route=ROUTE_GEMM, m=m, k=k, n=n, pm=pm, pn=pn,
                bm=bm, bk=bk, bn=bn)


# --------------------------------------------------------------------------
# Execution
# --------------------------------------------------------------------------
def _pad_axis(a: jax.Array, axis: int, to: int) -> jax.Array:
    if a.shape[axis] == to:
        return a
    pads = [(0, 0)] * a.ndim
    pads[axis] = (0, to - a.shape[axis])
    return jnp.pad(a, pads)


def _count_traffic(p: Plan, k: int, n_read: int) -> None:
    """Record plane-stream traffic for one routed call (trace-time)."""
    if p.route == ROUTE_GEMV:
        tiles = (p.pn // p.bn) * (k // p.bk)
    elif p.route == ROUTE_GEMM:
        tiles = (p.pm // p.bm) * (p.pn // p.bn) * (k // p.bk)
    else:
        tiles = 1
    words = k // PLANE * p.pn
    traffic[f"{p.route}:planes{n_read}"] += 1
    traffic["plane_reads"] += n_read * tiles
    traffic["plane_words_read"] += n_read * words
    traffic["plane_words_full"] += 3 * words
    if _phase:
        traffic[f"phase:{_phase}:plane_words_read"] += n_read * words
        traffic[f"phase:{_phase}:plane_words_full"] += 3 * words


def packed_matmul(
    x: jax.Array,
    planes: jax.Array,
    scales: jax.Array,
    *,
    group_size: int,
    use_kernel: bool = True,
    interpret: bool | None = None,
    plane_mask: jax.Array | None = None,
    sign_mag: bool = False,
    plane_major: bool = False,
    demand_drop: int = 0,
) -> jax.Array:
    """x (M,K) @ decode(planes (K//32,3,N), scales (K//G,N)) -> (M,N) f32.

    The one entry point every packed matmul goes through: plans on the
    static shapes, zero-pads ragged M/N to the fitted tile, runs the
    routed kernel, and slices the pad back off.  Never materializes the
    dense weight.

    ``plane_mask`` (M,) int32 — one 3-bit code mask per x row, values from
    :data:`MASK_VARIANTS` — makes the matmul quality-tiered PER ROW: row m
    contracts against the weight decoded under its own mask, bit-identical
    to the unmasked matmul on ``truncate(drop_m)`` planes.  The mask is a
    traced operand split into a fixed variant activation stack, so a
    tier change is a data change (mask flip), never a retrace; plan/route
    and tile fitting are identical to the unmasked call.

    ``sign_mag`` selects the wire-v2 sign-magnitude decoder;
    ``plane_major`` marks ``planes`` as (3, K//32, N) MSB-first, the layout
    whose HBM read shortens with demand; ``demand_drop`` (static, 0..2) is
    the batch demand floor: every live row drops at least that many planes,
    so the kernel only streams/decodes the ``3 - demand_drop`` demanded
    planes (plane-major) and variants ``MASK_VARIANTS[demand_drop:]``.
    Rows whose mask demands a pruned variant contribute zeros; the caller
    (engine demand vector) guarantees no live row does."""
    m, k = x.shape
    n = planes.shape[-1]
    if not 0 <= demand_drop < 3:
        raise ValueError(f"demand_drop must be 0..2, got {demand_drop}")
    if plane_mask is None and not plane_major:
        demand_drop = 0  # interleaved unmasked has nothing to prune
    p = plan(m, k, n, group_size, use_kernel=use_kernel)
    counters[p.route] += 1
    counters[f"{p.route}:{'padded' if p.padded else 'exact'}"] += 1
    # interleaved planes cannot shorten the read: all 3 planes stream.
    n_read = 3 - demand_drop if plane_major else 3
    _count_traffic(p, k, n_read)
    if plane_mask is not None:
        counters[f"{p.route}:masked"] += 1
        # variant split: xs[i] keeps exactly the rows masked
        # MASK_VARIANTS[demand_drop + i] (a row matches one variant; others
        # contribute exact zeros).  Pad rows carry mask 0 -> no variant ->
        # exact zero rows, as before.
        sel = jnp.stack([plane_mask == v for v in MASK_VARIANTS[demand_drop:]])
        xs = jnp.where(sel[:, :, None], x[None], 0).astype(x.dtype)

    if p.route == ROUTE_XLA:
        if plane_mask is not None:
            return ref.qsq_matmul_masked_ref(
                xs, planes, scales, group_size, sign_mag=sign_mag,
                plane_major=plane_major, demand_drop=demand_drop)
        return ref.qsq_matmul_ref(
            x, planes, scales, group_size, sign_mag=sign_mag,
            plane_major=plane_major, n_planes=3 - demand_drop)

    from repro.kernels import ops  # deferred: keeps pallas off cold paths

    pp = _pad_axis(planes, 2, p.pn)
    sp = _pad_axis(scales, 1, p.pn)
    if plane_mask is not None:
        xsp = _pad_axis(xs, 1, p.pm)
        if p.route == ROUTE_GEMV:
            out = ops.qsq_matvec_masked(xsp, pp, sp, group_size=group_size,
                                        bk=p.bk, bn=p.bn, interpret=interpret,
                                        sign_mag=sign_mag,
                                        plane_major=plane_major,
                                        demand_drop=demand_drop)
        else:
            out = ops.qsq_matmul_masked(xsp, pp, sp, group_size=group_size,
                                        bm=p.bm, bk=p.bk, bn=p.bn,
                                        interpret=interpret,
                                        sign_mag=sign_mag,
                                        plane_major=plane_major,
                                        demand_drop=demand_drop)
        return out[:m, :n] if p.padded else out

    xp = _pad_axis(x, 0, p.pm)
    if p.route == ROUTE_GEMV:
        out = ops.qsq_matvec(xp, pp, sp, group_size=group_size,
                             bk=p.bk, bn=p.bn, interpret=interpret,
                             sign_mag=sign_mag, plane_major=plane_major,
                             demand_drop=demand_drop)
    else:
        out = ops.qsq_matmul(xp, pp, sp, group_size=group_size,
                             bm=p.bm, bk=p.bk, bn=p.bn, interpret=interpret,
                             sign_mag=sign_mag, plane_major=plane_major,
                             demand_drop=demand_drop)
    return out[:m, :n] if p.padded else out

"""Pallas TPU kernel: QSQ encode (Eq. 9 + nearest-level assignment).

Used by the checkpoint writer and the gradient compressor, where encode speed
matters (grads are encoded every step before the cross-pod all-reduce).

Layout:
  w       (K, N) f32/bf16   input weights/grads, grouped along K
  codes   (K, N) int32      Table II codes (packed to bit-planes by the caller;
                            int32 because TPU Pallas prefers 32-bit stores)
  scales  (K//G, N) f32     per-group scalars

Grid: (K//bk, N//bn).  bk must be a multiple of the group size so each block
owns whole groups (the reduction for alpha never crosses a block boundary).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x.
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _qsq_quantize_kernel(w_ref, codes_ref, scales_ref, *, group_size: int, phi: int):
    bk, bn = w_ref.shape
    ng = bk // group_size
    w = w_ref[...].astype(jnp.float32).reshape(ng, group_size, bn)

    # Eq. 9: alpha = sum|w| / (phi * N) per group
    alpha = jnp.sum(jnp.abs(w), axis=1) / (phi * group_size)  # (ng, bn)
    safe = jnp.where(alpha == 0, 1.0, alpha)

    # nearest-level assignment over {0, +-1, +-2, +-4} capped by phi
    r = w / safe[:, None, :]
    a = jnp.abs(r)
    mag = jnp.where(a < 0.5, 0, jnp.where(a < 1.5, 1, jnp.where(a < 3.0, 2, 4)))
    max_level = {1: 1, 2: 2, 4: 4}[phi]
    mag = jnp.minimum(mag, max_level)
    # level -> Table II code: pos {1,2,4}->{1,2,3}; neg -> +3
    mag_idx = jnp.where(mag == 4, 3, mag)
    code = jnp.where(r < 0, jnp.where(mag_idx > 0, mag_idx + 3, 0), mag_idx)

    codes_ref[...] = code.reshape(bk, bn).astype(jnp.int32)
    scales_ref[...] = alpha.astype(jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("group_size", "phi", "bk", "bn", "interpret")
)
def qsq_quantize(
    w: jax.Array,
    *,
    group_size: int,
    phi: int = 4,
    bk: int = 512,
    bn: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Encode w (K,N) -> (codes (K,N) int32, scales (K//G,N) f32)."""
    k, n = w.shape
    bk, bn = min(bk, k), min(bn, n)
    if k % bk or n % bn:
        raise ValueError(f"shape ({k},{n}) not divisible by tile ({bk},{bn})")
    if bk % group_size:
        raise ValueError(f"bk={bk} must be a multiple of group_size={group_size}")

    grid = (k // bk, n // bn)
    kernel = functools.partial(_qsq_quantize_kernel, group_size=group_size, phi=phi)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bk, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bk // group_size, bn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, n), jnp.int32),
            jax.ShapeDtypeStruct((k // group_size, n), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(w)

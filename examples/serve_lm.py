"""Serving example: batched greedy decoding from an exact or QSQ-wire model.

  PYTHONPATH=src python examples/serve_lm.py [--arch mamba2_1_3b]

Demonstrates the paper's edge flow end-to-end: the serving process receives
the 3-bit + scalar artifact (10x smaller than f32), decodes it with
shift/scale on arrival, and serves batched requests.
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.core.policy import QuantPolicy
from repro.core.qsq import QSQConfig
from repro.models.api import Model
from repro.models.base import init_params
from repro.quant import pack_pytree_wire, quantize_pytree
from repro.serve import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="deepseek_7b")
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=True)
    model = Model(cfg)
    descs = model.param_descs()
    params = init_params(jax.random.PRNGKey(0), descs)

    # "transmit" the model in QSQ wire form; passing descs groups matmul
    # weights along their contraction axis so the receiver can serve them
    # packed (bit-planes through the fused dequant-matmul), not just decode.
    wire = pack_pytree_wire(
        quantize_pytree(params, QuantPolicy(base=QSQConfig(group_size=16),
                                            min_numel=512), descs)
    )
    raw = sum(l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(params))
    wired = sum(
        np.asarray(l).size * 4 if hasattr(l, "size") else 0
        for l in jax.tree_util.tree_leaves(wire)
    )
    print(f"channel payload: {wired / 1e6:.2f} MB (raw {raw / 1e6:.2f} MB)")

    eng = ServeEngine.from_wire(model, wire, ServeConfig(batch_slots=4))
    print(f"serving {eng.n_packed_leaves} matmul weights straight from the "
          f"3-bit wire (no full-tree dequantize)")
    prompts = [[1, 2, 3, 4], [10, 20], [7, 7, 7]]
    t0 = time.time()
    outs = eng.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    for p, o in zip(prompts, outs):
        print(f"  prompt={p} -> {o}")
    n_tok = len(prompts) * args.max_new
    print(f"{n_tok} tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s, "
          f"batch={len(prompts)})")


if __name__ == "__main__":
    main()

"""Serving example: the quality-dial facade, compress -> save -> serve.

  PYTHONPATH=src python examples/serve_lm.py [--arch mamba2_1_3b]

Demonstrates the paper's edge flow end-to-end through `repro.api`: the
model is compressed once into a self-describing EdgeArtifact (3-bit codes
+ scalars, ~10x smaller than f32), saved, loaded back as the receiver
would, and served at every quality tier — lower tiers drop LSB bit-planes
from the least-sensitive layers (the CSD-truncation analogue) without
ever re-quantizing.
"""
import argparse
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax

from repro import api
from repro.configs import ARCH_IDS, get_arch
from repro.models.api import Model
from repro.models.base import init_params
from repro.quant import tree_bits_report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="deepseek_7b")
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=True)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_descs())

    # one call replaces quantize -> pack -> export: the artifact carries the
    # wire tree plus the tier spec and per-layer sensitivity ranking.
    artifact = api.compress(model, params)
    raw = sum(a.size * a.dtype.itemsize for a in jax.tree_util.tree_leaves(params))

    with tempfile.TemporaryDirectory() as d:
        path = artifact.save(Path(d) / "model.edge.npz")
        print(f"channel payload: {path.stat().st_size / 1e6:.2f} MB "
              f"(raw {raw / 1e6:.2f} MB)")

        # the edge side: load the self-describing artifact and dial quality
        received = api.load(path)
        prompts = [[1, 2, 3, 4], [10, 20], [7, 7, 7]]
        for tier in received.quality_names():
            eng = received.engine(quality=tier, batch_slots=4)
            rep = tree_bits_report(eng.params)
            t0 = time.time()
            outs = eng.generate(prompts, max_new=args.max_new)
            dt = time.time() - t0
            n_tok = len(prompts) * args.max_new
            print(f"tier {tier!r}: {eng.n_packed_leaves} packed leaves, "
                  f"{rep['bits'] / 8e3:.1f} kB weights, "
                  f"{n_tok / dt:.1f} tok/s")
            for p, o in zip(prompts, outs, strict=True):
                print(f"    prompt={p} -> {o}")


if __name__ == "__main__":
    main()

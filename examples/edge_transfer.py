"""The paper's headline scenario, end to end:

  cloud:  train LeNet -> QSQ-encode (3-bit codes + scalars) -> write to the
          "channel" (a file standing in for the network link)
  edge:   read the artifact -> decode with shift/scale only -> run inference

Reports the channel payload size (Eq. 11/12), decode time, and the accuracy
delta — the three quantities the paper trades against each other.

  PYTHONPATH=src python examples/edge_transfer.py
"""
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import numpy as np

from benchmarks.common import train_cnn
from repro.checkpoint.manager import CheckpointManager, CheckpointConfig, _flatten
from repro.core.policy import QuantPolicy
from repro.core.qsq import QSQConfig
from repro.models.cnn import LENET, cnn_accuracy
from repro.quant import (
    dequantize_pytree, pack_pytree_wire, quantize_pytree, unpack_pytree_wire,
)


def main():
    print("== CLOUD ==")
    params, tr_i, tr_l, ev_i, ev_l = train_cnn(LENET, steps=300, n=1024)
    acc_fp = cnn_accuracy(params, LENET, ev_i, ev_l)
    print(f"trained LeNet: accuracy {acc_fp:.4f}")

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(CheckpointConfig(directory=d, async_save=False))
        policy = QuantPolicy(
            base=QSQConfig(phi=4, group_size=16, refit_alpha=True), min_numel=256
        )
        t0 = time.time()
        wire_path = mgr.export_wire(params, policy)
        t_enc = time.time() - t0

        raw_bytes = sum(l.size * l.dtype.itemsize
                        for l in jax.tree_util.tree_leaves(params))
        wire_bytes = wire_path.stat().st_size
        print(f"encoded in {t_enc * 1e3:.0f} ms -> channel payload "
              f"{wire_bytes / 1e3:.1f} kB (raw {raw_bytes / 1e3:.1f} kB, "
              f"{(1 - wire_bytes / raw_bytes) * 100:.1f}% saved)")

        print("== EDGE ==")
        data = np.load(wire_path)
        # rebuild the wire pytree from the flat archive
        qp0 = quantize_pytree(params, policy)
        wire_like = pack_pytree_wire(qp0)
        flat, treedef = jax.tree_util.tree_flatten_with_path(wire_like)
        leaves = [data[jax.tree_util.keystr(p)] for p, _ in flat]
        wire = jax.tree_util.tree_unflatten(treedef, leaves)

        t0 = time.time()
        decoded = dequantize_pytree(unpack_pytree_wire(wire), like=params)
        jax.block_until_ready(jax.tree_util.tree_leaves(decoded)[0])
        t_dec = time.time() - t0
        acc_q = cnn_accuracy(decoded, LENET, ev_i, ev_l)
        print(f"decoded in {t_dec * 1e3:.0f} ms (shift/scale only) -> "
              f"accuracy {acc_q:.4f} (drop {acc_fp - acc_q:+.4f})")
        print(f"paper comparison: 82.49% size reduction, ~1.1 point drop")


if __name__ == "__main__":
    main()

"""The paper's headline scenario, end to end:

  cloud:  train LeNet -> compress to an EdgeArtifact (3-bit codes +
          scalars) -> write to the "channel" (a file standing in for the
          network link)
  edge:   load the artifact -> decode with shift/scale only -> run
          inference, at more than one quality tier from the SAME payload

Reports the channel payload size (Eq. 11/12), decode time, and the
accuracy delta — the three quantities the paper trades against each other
— and then turns the quality dial: the 'lo' tier drops LSB code planes
from the least-sensitive layers (the CSD-truncation analogue) without a
second transmission or any re-quantization.

  PYTHONPATH=src python examples/edge_transfer.py
"""
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax

from benchmarks.common import train_cnn
from repro import api
from repro.core.policy import QuantPolicy
from repro.core.qsq import QSQConfig
from repro.models.cnn import LENET, cnn_accuracy


def main():
    print("== CLOUD ==")
    params, tr_i, tr_l, ev_i, ev_l = train_cnn(LENET, steps=300, n=1024)
    acc_fp = cnn_accuracy(params, LENET, ev_i, ev_l)
    print(f"trained LeNet: accuracy {acc_fp:.4f}")

    policy = QuantPolicy(
        base=QSQConfig(phi=4, group_size=16, refit_alpha=True), min_numel=256
    )
    with tempfile.TemporaryDirectory() as d:
        t0 = time.time()
        # model-free compress: no serving Model, but the artifact still
        # carries the tier spec + sensitivity ranking for dense decode.
        artifact = api.compress(None, params, policy=policy)
        wire_path = artifact.save(Path(d) / "lenet.edge.npz")
        t_enc = time.time() - t0

        raw_bytes = sum(a.size * a.dtype.itemsize
                        for a in jax.tree_util.tree_leaves(params))
        wire_bytes = wire_path.stat().st_size
        print(f"encoded in {t_enc * 1e3:.0f} ms -> channel payload "
              f"{wire_bytes / 1e3:.1f} kB (raw {raw_bytes / 1e3:.1f} kB, "
              f"{(1 - wire_bytes / raw_bytes) * 100:.1f}% saved)")

        print("== EDGE ==")
        received = api.load(wire_path)
        t0 = time.time()
        decoded = received.dense_params(quality="hi", like=params)
        jax.block_until_ready(jax.tree_util.tree_leaves(decoded)[0])
        t_dec = time.time() - t0
        acc_q = cnn_accuracy(decoded, LENET, ev_i, ev_l)
        print(f"decoded in {t_dec * 1e3:.0f} ms (shift/scale only) -> "
              f"accuracy {acc_q:.4f} (drop {acc_fp - acc_q:+.4f})")
        print(f"paper comparison: 82.49% size reduction, ~1.1 point drop")

        # the quality dial: same payload, LSB planes dropped at decode time
        for tier in ("mid", "lo"):
            deq = received.dense_params(quality=tier, like=params)
            acc_t = cnn_accuracy(deq, LENET, ev_i, ev_l)
            n_trunc = len(received.drop_map(tier))
            print(f"tier {tier!r}: {n_trunc} layers LSB-truncated -> "
                  f"accuracy {acc_t:.4f} (drop {acc_fp - acc_t:+.4f}, "
                  f"no re-transmission)")


if __name__ == "__main__":
    main()

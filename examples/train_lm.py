"""End-to-end training driver: LM training with checkpoint/restart, QSQ
gradient compression, straggler watchdog, and a QSQ wire export at the end.

  PYTHONPATH=src python examples/train_lm.py --steps 300

On this 1-core CPU container the default runs the reduced smollm config
(same family/code path as the 135M model); on a pod, pass --full to train
the real config under the production mesh.  A mid-size (~20M param) variant
is available with --mid.
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import dataclasses

import jax

from repro.checkpoint import CheckpointConfig
from repro.configs import get_arch
from repro.core.policy import QuantPolicy
from repro.core.qsq import QSQConfig
from repro.data.pipeline import LMDataConfig, lm_batch
from repro.models.api import Model
from repro.optim import AdamWConfig, GradCompressionConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mid", action="store_true", help="~20M param variant")
    ap.add_argument("--full", action="store_true", help="full 135M config")
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--grad-compression", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_arch("smollm_135m", smoke=not args.full)
    if args.mid:
        cfg = dataclasses.replace(cfg, n_layers=6, d_model=256, n_heads=8,
                                  n_kv=4, d_ff=1024, vocab=4096)
    model = Model(cfg)
    data = LMDataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)

    tcfg = TrainerConfig(
        total_steps=args.steps,
        log_every=max(args.steps // 20, 1),
        opt=AdamWConfig(lr=3e-3),
        compression=GradCompressionConfig(enabled=args.grad_compression,
                                          min_numel=4096),
        checkpoint=CheckpointConfig(directory=args.ckpt, every_steps=100),
    )
    trainer = Trainer(model, tcfg, lambda s: lm_batch(data, s))
    state, start = trainer.init_state()
    if start:
        print(f"resumed from checkpoint at step {start}")
    state, last = trainer.run(state, start)

    for m in trainer.metrics_log:
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"{m['sec_per_step'] * 1e3:.0f} ms")
    if trainer.straggler_events:
        print(f"straggler events: {trainer.straggler_events}")

    # export the paper's wire artifact
    wire_path = trainer.ckpt.export_wire(
        state.params, QuantPolicy(base=QSQConfig(group_size=16), min_numel=512)
    )
    import os

    full = sum(a.size * a.dtype.itemsize
               for a in jax.tree_util.tree_leaves(state.params))
    print(f"wire export: {wire_path} "
          f"({os.path.getsize(wire_path) / 1e6:.2f} MB vs {full / 1e6:.2f} MB raw)")


if __name__ == "__main__":
    main()

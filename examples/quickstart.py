"""Quickstart: the paper's whole methodology in one script.

Trains LeNet on the synthetic image task, applies Quality Scalable
Quantization at phi = 1/2/4, reports accuracy vs quality level (Fig. 7),
model-size savings (Eq. 11/12 / Fig. 9) and the +zeros effect, then shows
the CSD quality-scalable-multiplier rounding (Fig. 11).

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import numpy as np

from benchmarks.common import train_cnn
from repro.core.csd import csd_round, partial_product_savings
from repro.core.policy import QuantPolicy
from repro.core.qsq import QSQConfig, zeros_fraction
from repro.models.cnn import LENET, cnn_accuracy
from repro.quant import dequantize_pytree, pytree_bits_report, quantize_pytree


def main():
    print("1) training LeNet (synthetic MNIST-shaped task)...")
    params, tr_i, tr_l, ev_i, ev_l = train_cnn(LENET, steps=150)
    acc = cnn_accuracy(params, LENET, ev_i, ev_l)
    print(f"   float accuracy: {acc:.4f}")

    print("2) Quality Scalable Quantization at three quality levels:")
    for phi in (1, 2, 4):
        policy = QuantPolicy(base=QSQConfig(phi=phi, group_size=16), min_numel=256)
        qp = quantize_pytree(params, policy)
        deq = dequantize_pytree(qp, like=params)
        acc_q = cnn_accuracy(deq, LENET, ev_i, ev_l)
        rep = pytree_bits_report(params, qp)
        print(f"   phi={phi}: accuracy={acc_q:.4f} "
              f"(drop {acc - acc_q:+.4f})  model-size savings="
              f"{rep['memory_savings'] * 100:.2f}%")

    print("3) zeros introduced by quantization (paper: +6%):")
    policy = QuantPolicy(base=QSQConfig(phi=4, group_size=16), min_numel=256)
    qp = quantize_pytree(params, policy)
    w = jax.tree_util.tree_leaves(params)[0]
    from repro.core.qsq import QSQTensor

    qleaves = [q for q in jax.tree_util.tree_leaves(
        qp.tree, is_leaf=lambda x: isinstance(x, QSQTensor))
        if isinstance(q, QSQTensor)]
    z_fp = np.mean([float(zeros_fraction(a)) for a in jax.tree_util.tree_leaves(params) if a.ndim >= 2])
    z_q = np.mean([float(zeros_fraction(q.levels)) for q in qleaves])
    print(f"   zeros: {z_fp * 100:.2f}% -> {z_q * 100:.2f}%")

    print("4) CSD quality-scalable multiplier (weight-rounding view):")
    w = jax.tree_util.tree_leaves(params)[0]
    for k in (1, 2, 3):
        err = float(np.mean((np.asarray(w) - np.asarray(csd_round(w, k))) ** 2))
        s = float(partial_product_savings(w, k))
        print(f"   k={k} digits: mse={err:.2e}, partial products skipped={s * 100:.1f}%")

    print("done.")


if __name__ == "__main__":
    main()

"""Unit + property tests for the QSQ quantizer (Eq. 5-10, Table II).

Property tests use hypothesis when available, otherwise a fixed seed sweep.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on the environment
    HAS_HYPOTHESIS = False

from repro.core import (
    LEVEL_TABLE,
    QSQConfig,
    codes_to_levels,
    dequantize,
    exhaustive_threshold_search,
    levels_for_phi,
    levels_to_codes,
    quantization_error,
    quantize,
    theta_levels,
    zeros_fraction,
)


def _randw(shape, seed=0, scale=0.1):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


# ---------------------------------------------------------------- Eq. 8
def test_theta_levels_eq8():
    # phi=1 -> {0,1}; phi=2 -> {0,1,2}; phi=4 -> {0,1,2,4}
    assert theta_levels(1) == 2
    assert theta_levels(2) == 3
    assert theta_levels(4) == 4
    with pytest.raises(ValueError):
        theta_levels(3)


def test_levels_for_phi():
    assert set(np.asarray(levels_for_phi(1)).tolist()) == {0, 1, -1}
    assert set(np.asarray(levels_for_phi(2)).tolist()) == {0, 1, 2, -1, -2}
    assert set(np.asarray(levels_for_phi(4)).tolist()) == {0, 1, 2, 4, -1, -2, -4}


# ---------------------------------------------------------------- Eq. 9
def test_alpha_formula():
    w = _randw((32, 8), seed=1)
    for phi in (1, 2, 4):
        q = quantize(w, QSQConfig(phi=phi, group_size=16))
        wg = np.asarray(w).reshape(2, 16, 8)
        expected = np.abs(wg).sum(axis=1) / (phi * 16)
        np.testing.assert_allclose(np.asarray(q.scales), expected, rtol=1e-5)


# ---------------------------------------------------------------- Table II
def test_code_table_roundtrip():
    levels = jnp.array([0, 1, 2, 4, -1, -2, -4], dtype=jnp.int8)
    codes = levels_to_codes(levels)
    np.testing.assert_array_equal(np.asarray(codes), [0, 1, 2, 3, 4, 5, 6])
    back = codes_to_levels(codes)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(levels))


def test_level_table_matches_paper():
    # Table II: 000->0, 001->+1, 010->+2, 011->+4, 100->-1, 101->-2, 110->-4
    assert LEVEL_TABLE.tolist() == [0, 1, 2, 4, -1, -2, -4, 0]


# ---------------------------------------------------------------- quantize
@pytest.mark.parametrize("phi", [1, 2, 4])
@pytest.mark.parametrize("assign", ["nearest", "sigma"])
def test_levels_within_alphabet(phi, assign):
    w = _randw((64, 16), seed=2)
    q = quantize(w, QSQConfig(phi=phi, group_size=16, assign=assign))
    allowed = set(np.asarray(levels_for_phi(phi)).tolist())
    assert set(np.unique(np.asarray(q.levels)).tolist()) <= allowed


def test_nearest_minimizes_given_alpha():
    """'nearest' must beat/tie any other assignment at fixed alpha (Eq. 5)."""
    w = _randw((64, 4), seed=3)
    cfg = QSQConfig(phi=4, group_size=16, assign="nearest")
    q = quantize(w, cfg)
    err_nearest = float(quantization_error(w, q))
    err_sigma = float(
        quantization_error(w, quantize(w, QSQConfig(phi=4, group_size=16, assign="sigma")))
    )
    assert err_nearest <= err_sigma + 1e-6


def test_quality_scales_with_phi():
    """Fig. 7: more levels (higher phi) => lower reconstruction error."""
    w = _randw((256, 16), seed=4)
    errs = {
        phi: float(quantization_error(w, quantize(w, QSQConfig(phi=phi, group_size=16))))
        for phi in (1, 2, 4)
    }
    assert errs[4] <= errs[2] <= errs[1]


def test_zeros_increase():
    """The paper reports ~+6% zeros after QSQ."""
    w = _randw((512, 16), seed=5)
    q = quantize(w, QSQConfig(phi=4, group_size=16))
    assert float(zeros_fraction(q.levels)) > float(zeros_fraction(w))


def test_exhaustive_threshold_search_improves_or_ties():
    w = _randw((128, 8), seed=6)
    base = QSQConfig(phi=4, group_size=16, assign="sigma", delta=3.0, gamma_frac=0.75)
    best = exhaustive_threshold_search(w, base)
    e_base = float(quantization_error(w, quantize(w, base)))
    e_best = float(quantization_error(w, quantize(w, best)))
    assert e_best <= e_base + 1e-6


# ---------------------------------------------------------------- properties
def _check_reconstruction_bounded(seed, phi, log_g, scale):
    g = 2**log_g
    w = jax.random.normal(jax.random.PRNGKey(seed), (4 * g, 4)) * scale
    q = quantize(w, QSQConfig(phi=phi, group_size=g))
    wh = np.asarray(dequantize(q))
    max_level = {1: 1, 2: 2, 4: 4}[phi]
    bound = max_level * np.repeat(np.asarray(q.scales), g, axis=0)
    assert (np.abs(wh) <= bound + 1e-5).all()


def _check_sign_preserved(seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (64, 4)) * 0.2
    q = quantize(w, QSQConfig(phi=4, group_size=16))
    prod = np.asarray(w) * np.asarray(q.levels).astype(np.float32)
    assert (prod >= -1e-9).all()


def _check_scale_equivariance(seed, phi):
    w = jax.random.normal(jax.random.PRNGKey(seed), (32, 4)) * 0.1
    c = 7.5
    q1 = quantize(w, QSQConfig(phi=phi, group_size=16))
    q2 = quantize(c * w, QSQConfig(phi=phi, group_size=16))
    np.testing.assert_array_equal(np.asarray(q1.levels), np.asarray(q2.levels))
    np.testing.assert_allclose(np.asarray(q2.scales), c * np.asarray(q1.scales), rtol=1e-5)


def _check_refit_never_worse(seed, phi):
    import dataclasses

    w = jax.random.normal(jax.random.PRNGKey(seed), (64, 8)) * 0.15
    base = QSQConfig(phi=phi, group_size=16)
    e_paper = float(quantization_error(w, quantize(w, base)))
    e_refit = float(
        quantization_error(w, quantize(w, dataclasses.replace(base, refit_alpha=True)))
    )
    assert e_refit <= e_paper + 1e-5


if HAS_HYPOTHESIS:

    @settings(deadline=None, max_examples=25)
    @given(
        seed=st.integers(0, 2**31 - 1),
        phi=st.sampled_from([1, 2, 4]),
        log_g=st.integers(0, 5),
        scale=st.floats(1e-3, 10.0),
    )
    def test_property_reconstruction_bounded(seed, phi, log_g, scale):
        """|w_hat| <= max_level * alpha and error <= |w| + |w_hat| elementwise."""
        _check_reconstruction_bounded(seed, phi, log_g, scale)

    @settings(deadline=None, max_examples=25)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_property_sign_preserved(seed):
        """Quantization never flips a weight's sign (it may zero it)."""
        _check_sign_preserved(seed)

    @settings(deadline=None, max_examples=15)
    @given(seed=st.integers(0, 2**31 - 1), phi=st.sampled_from([1, 2, 4]))
    def test_property_scale_equivariance(seed, phi):
        """quantize(c*w) == c * quantize(w) for c > 0 (alpha is linear in |w|)."""
        _check_scale_equivariance(seed, phi)

    @settings(deadline=None, max_examples=20)
    @given(seed=st.integers(0, 2**31 - 1), phi=st.sampled_from([1, 2, 4]))
    def test_property_refit_never_worse(seed, phi):
        """Least-squares alpha refit (beyond-paper) can only reduce Eq. 5 error."""
        _check_refit_never_worse(seed, phi)

else:

    @pytest.mark.parametrize("seed,phi,log_g,scale",
                             [(0, 1, 0, 1e-3), (1, 2, 3, 0.1), (2, 4, 5, 10.0)])
    def test_property_reconstruction_bounded(seed, phi, log_g, scale):
        _check_reconstruction_bounded(seed, phi, log_g, scale)

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_property_sign_preserved(seed):
        _check_sign_preserved(seed)

    @pytest.mark.parametrize("seed,phi", [(0, 1), (1, 2), (2, 4)])
    def test_property_scale_equivariance(seed, phi):
        _check_scale_equivariance(seed, phi)

    @pytest.mark.parametrize("seed,phi", [(0, 1), (1, 2), (2, 4)])
    def test_property_refit_never_worse(seed, phi):
        _check_refit_never_worse(seed, phi)


def test_nbits_eq12():
    w = _randw((64, 32), seed=7)
    q = quantize(w, QSQConfig(phi=4, group_size=16))
    # 3 bits per element + 32 per scalar group
    assert q.nbits() == 3 * 64 * 32 + 32 * (64 // 16) * 32

"""Continuous-batching scheduler invariants.

The contract under test: requests join a RUNNING decode without flushing
or perturbing batch mates.  Concretely —

* ``generate()`` through the scheduler is token-identical to the static
  two-program path for the same prompt set (dense family);
* a prompt admitted mid-decode produces exactly the tokens it produces
  served alone, and does not change the tokens of the slot it joined
  (extends the PR 3 batch-isolation guarantee across TIME);
* evicting a finished request and re-admitting into the same slot is
  clean — the lane insert replaces the whole lane;
* ``step()`` traces its programs once: admissions and evictions are mask
  flips, not shape changes (asserted via the kernel dispatch counters,
  which count packed-matmul routing at TRACE time only).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import Model
from repro.models.base import init_params
from repro.serve import ServeConfig, ServeEngine
from repro.serve.scheduler import FinishReason, Scheduler, SlotState


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_arch("deepseek_7b", smoke=True)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_descs())
    static = ServeEngine(model, params,
                         ServeConfig(batch_slots=4, continuous=False))
    return model, params, static


def _solo(static, prompt, max_new):
    return static.generate([prompt], max_new=max_new)[0]


# --------------------------------------------------------------------------
# Host-side state machine
# --------------------------------------------------------------------------
def test_scheduler_state_machine():
    s = Scheduler(2)
    assert not s.has_work
    r0 = s.submit([1, 2], max_new=2, arrival=0)
    r1 = s.submit([3], max_new=1, arrival=0)
    r2 = s.submit([4], max_new=1, arrival=1)  # queued: no third slot
    pairs = list(s.admissible())
    assert [slot for slot, _ in pairs] == [0, 1]
    assert [req.rid for _, req in pairs] == [r0, r1]
    assert len(s.queue) == 1  # r2 still queued
    for slot, req in pairs:
        s.activate(slot, req, step=0)
        assert s.states[slot] is SlotState.PREFILLING
        s.start_decoding(slot)
        assert s.states[slot] is SlotState.DECODING
    assert s.record(1, 7, step=0)  # r1: max_new=1 -> done immediately
    assert s.states[1] is SlotState.DONE
    done = s.evict(1)
    assert done.rid == r1 and s.states[1] is SlotState.FREE
    assert done.waiting == 0 and done.latency == 0
    # freed slot now admits the queued request
    assert [slot for slot, _ in s.admissible()] == [1]
    assert not s.record(0, 5, step=1)  # r0: 1 of 2 tokens
    assert s.record(0, 6, step=2)
    s.evict(0)
    assert s.completed[r0].out == [5, 6]
    assert s.completed[r0].latency == 2
    assert r2 not in s.completed


def test_scheduler_poll_structured_status():
    s = Scheduler(1)
    rid = s.submit([1], max_new=1, arrival=0)
    st = s.poll(rid)
    assert st.state == "queued" and st.finish_reason is None
    assert st.tokens is None  # non-terminal: no token hand-out yet
    slot, req = next(s.admissible())
    s.activate(slot, req, step=0)
    s.start_decoding(slot)
    s.record(slot, 9, step=0)
    s.evict(slot)
    st = s.poll(rid)
    assert st.state == "done" and st.finish_reason is FinishReason.DONE
    assert st.tokens == [9] and st.ok and st.done
    assert s.poll(rid).tokens == [9]  # per-rid polls are idempotent
    with pytest.raises(KeyError, match="unknown"):
        s.poll(rid + 1)  # never issued
    # the bare poll pops newly-terminal statuses exactly once
    batch = s.poll()
    assert batch[rid].tokens == [9]
    assert s.poll() == {}
    assert s.completed[rid].out == [9]  # stats survive the claim


def test_scheduler_submit_validation():
    s = Scheduler(1)
    with pytest.raises(ValueError, match="at least one token"):
        s.submit([], max_new=4, arrival=0)
    with pytest.raises(ValueError, match="max_new"):
        s.submit([1], max_new=0, arrival=0)
    with pytest.raises(ValueError, match="slot"):
        Scheduler(0)


# --------------------------------------------------------------------------
# Engine integration: exactness across scheduling decisions
# --------------------------------------------------------------------------
def test_continuous_generate_matches_static(dense_setup):
    """generate() is a submit-all/drain wrapper: token-identical to the
    static one-batch path for the same prompt set."""
    model, params, static = dense_setup
    cont = ServeEngine(model, params, ServeConfig(batch_slots=4))
    for prompts, max_new in [
        ([[1, 2, 3]], 6),
        ([[1, 2, 3], [9, 9], [100, 42, 7, 8]], 8),
        ([[5], [5, 6, 7, 8, 9, 10]], 5),
    ]:
        assert cont.generate(prompts, max_new=max_new) == \
            static.generate(prompts, max_new=max_new)
    # zero-length decode stays a no-op on every path (legacy contract)
    assert cont.generate([[1, 2]], max_new=0) == [[]]
    assert static.generate([[1, 2]], max_new=0) == [[]]


def test_midstream_admission_exact_and_isolated(dense_setup):
    """A prompt admitted MID-DECODE yields exactly its solo tokens, and the
    slot it joined keeps exactly the tokens it was already producing."""
    model, params, static = dense_setup
    eng = ServeEngine(model, params,
                      ServeConfig(batch_slots=2, max_prompt=8, max_len=32))
    r1 = eng.submit([1, 2, 3], max_new=10)
    for _ in range(4):
        eng.step()  # r1 is several tokens deep
    r2 = eng.submit([9, 9], max_new=6)  # joins the running decode
    out = eng.run_until_drained()
    assert out[r1].tokens == _solo(static, [1, 2, 3], 10)
    assert out[r2].tokens == _solo(static, [9, 9], 6)


def test_evict_readmit_reuses_slot(dense_setup):
    """One slot, three queued requests: each admission reuses the lane the
    previous request vacated, and every result matches its solo run."""
    model, params, static = dense_setup
    eng = ServeEngine(model, params,
                      ServeConfig(batch_slots=1, max_prompt=8, max_len=24))
    prompts = [[1, 2, 3], [9, 9], [100, 42, 7]]
    rids = [eng.submit(p, max_new=4) for p in prompts]
    out = eng.run_until_drained()
    sched = eng._session.sched
    assert sched.states == [SlotState.FREE]
    assert not sched.has_work
    for rid, p in zip(rids, prompts, strict=True):
        assert out[rid].tokens == _solo(static, p, 4)
    # the three admissions were strictly sequential through slot 0
    admits = sorted(sched.completed[r].admitted for r in rids)
    assert admits[0] < admits[1] < admits[2]


def test_poll_streams_results_incrementally(dense_setup):
    model, params, static = dense_setup
    eng = ServeEngine(model, params,
                      ServeConfig(batch_slots=2, max_prompt=8, max_len=24))
    r_short = eng.submit([4, 5], max_new=2)
    r_long = eng.submit([6, 7], max_new=8)
    seen = {}
    for _ in range(3):
        eng.step()
        seen.update(eng.poll())
    assert r_short in seen and r_long not in seen  # short one finished first
    assert seen[r_short].finish_reason is FinishReason.DONE
    live = eng.poll(r_long)  # structured: still decoding, keep stepping
    assert live.finish_reason is None and live.state == "decoding"
    assert live.n_tokens > 0 and live.tokens is None
    out = eng.run_until_drained()  # drains AND polls the remainder
    assert out[r_long].tokens == _solo(static, [6, 7], 8)
    assert r_long not in eng.poll()  # bare polls hand out once
    assert eng.poll(r_long).tokens == out[r_long].tokens  # per-rid: idempotent
    assert eng.completed_requests[r_long].out == out[r_long].tokens


def test_submit_rejects_unsupported(dense_setup):
    model, params, _ = dense_setup
    hot = ServeEngine(model, params,
                      ServeConfig(batch_slots=2, temperature=0.7))
    with pytest.raises(ValueError, match="greedy-only"):
        hot.submit([1, 2])
    eng = ServeEngine(model, params,
                      ServeConfig(batch_slots=2, max_prompt=4, max_len=16))
    with pytest.raises(ValueError, match="prefill window"):
        eng.submit([1, 2, 3, 4, 5])  # longer than max_prompt
    with pytest.raises(ValueError, match="max_len"):
        eng.submit([1, 2], max_new=100)


def test_recurrent_family_submit_rejected_generate_works():
    cfg = get_arch("mamba2_1_3b", smoke=True)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_descs())
    eng = ServeEngine(model, params, ServeConfig(batch_slots=2))
    with pytest.raises(ValueError, match="attention famil"):
        eng.submit([1, 2])
    assert len(eng.generate([[3, 1]], max_new=4)[0]) == 4  # static fallback


# --------------------------------------------------------------------------
# Trace stability: admissions/evictions are mask flips, not recompiles
# --------------------------------------------------------------------------
def test_step_traces_once_across_admissions(no_retrace):
    """After one admission + one decode step have traced the programs,
    further admissions, evictions and steps must not retrace: the packed
    dispatch counters (incremented ONLY at trace time) stay frozen."""
    from repro.configs.base import ArchConfig
    from repro.core.policy import QuantPolicy
    from repro.core.qsq import QSQConfig
    from repro.models import Model as M
    from repro.quant import pack_pytree_wire, quantize_pytree

    cfg = ArchConfig(name="smollm-like", family="dense", n_layers=2,
                     d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256,
                     dtype=jnp.float32, remat=False)
    model = M(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_descs())
    wire = pack_pytree_wire(quantize_pytree(
        params,
        QuantPolicy(base=QSQConfig(group_size=16, refit_alpha=True),
                    min_numel=512),
        model.param_descs(),
    ))
    from repro.quant.artifact import EdgeArtifact

    eng = EdgeArtifact(wire=wire, arch_config=cfg).engine(
        quality="hi", batch_slots=2, max_prompt=8, max_len=24)
    assert eng.n_packed_leaves > 0

    # warmup: one admission traces prefill+insert, one step traces decode
    eng.submit([1, 2, 3], max_new=3)
    eng.step()
    with no_retrace(eng._cont_step, eng._admit):
        r2 = eng.submit([9, 9], max_new=4)       # admission into slot 1
        r3 = eng.submit([5, 6, 7, 8], max_new=2)  # queued, admitted post-evict
        out = eng.run_until_drained()
    assert len(out[r2].tokens) == 4 and len(out[r3].tokens) == 2
    # and the jitted programs each compiled exactly one specialization
    assert eng._cont_step._cache_size() == 1
    assert eng._admit._cache_size() == 1


# --------------------------------------------------------------------------
# MoE dead-lane routing: FREE/DONE slots drop out of expert competition
# --------------------------------------------------------------------------
def _moe_model():
    import dataclasses

    from repro.configs.base import MoEConfig

    cfg = get_arch("mixtral_8x22b", smoke=True)
    # tight capacity so expert competition actually bites at decode width
    cfg = dataclasses.replace(
        cfg, moe=MoEConfig(n_experts=4, top_k=2, capacity_factor=0.3),
        window=None)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_descs())
    return model, params


def test_moe_dead_lane_out_of_expert_competition():
    """layers.moe with an active mask: a dead lane's token must not change
    live lanes' outputs (it used to claim capacity slots like a live batch
    mate), and its own output must be zero."""
    from repro.models import layers as L

    key = jax.random.PRNGKey(0)
    d, ff, e = 16, 32, 4
    p = init_params(key, L.moe_descs(d, ff, e))
    rng = np.random.RandomState(0)
    x1 = jnp.asarray(rng.randn(4, 1, d), jnp.float32)
    x2 = x1.at[1].set(jnp.asarray(rng.randn(1, d), jnp.float32))
    active = jnp.asarray([1, 0, 1, 1], jnp.int32)
    live = np.array([0, 2, 3])
    y1 = np.asarray(L.moe(p, x1, top_k=2, capacity_factor=0.3,
                          active=active)[0])
    y2 = np.asarray(L.moe(p, x2, top_k=2, capacity_factor=0.3,
                          active=active)[0])
    np.testing.assert_array_equal(y1[live], y2[live])
    assert (y1[1] == 0).all()
    # without the mask the dead token DOES perturb live lanes (the bug the
    # mask fixes) — guards against the test going vacuous
    z1 = np.asarray(L.moe(p, x1, top_k=2, capacity_factor=0.3)[0])
    z2 = np.asarray(L.moe(p, x2, top_k=2, capacity_factor=0.3)[0])
    assert not np.array_equal(z1[live], z2[live])


def test_moe_slot_history_invariance():
    """Mirror of the dense families' slot-history guarantee: an MoE
    request's tokens are invariant to DEAD lanes — a slot whose previous
    occupant finished leaves a frozen token that no longer competes for
    expert capacity."""
    model, params = _moe_model()
    scfg = ServeConfig(batch_slots=2, max_prompt=8, max_len=24)
    # history engine: a short request finishes first, freezing its last
    # token in the vacated lane while the probe request decodes
    hist = ServeEngine(model, params, scfg)
    r_warm = hist.submit([42, 17, 99], max_new=1)  # done at admission
    hist.step()
    assert hist.poll(r_warm).done
    r_probe = hist.submit([1, 2, 3], max_new=8)
    got = hist.run_until_drained()[r_probe].tokens
    # fresh engine: same probe, never-used second slot
    fresh = ServeEngine(model, params, scfg)
    r_solo = fresh.submit([1, 2, 3], max_new=8)
    want = fresh.run_until_drained()[r_solo].tokens
    assert got == want


def test_active_mask_freezes_dead_lanes(dense_setup):
    """A slot that finished early is a dead lane: its per-slot cache pos
    stops advancing while its batch mate keeps decoding."""
    model, params, _ = dense_setup
    eng = ServeEngine(model, params,
                      ServeConfig(batch_slots=2, max_prompt=8, max_len=32))
    eng.submit([1, 2, 3], max_new=2)   # finishes after one decode step
    eng.submit([9, 9], max_new=10)
    for _ in range(4):
        eng.step()
    pos = np.asarray(eng._session.cache.kv.pos)  # (L, B)
    assert (pos[:, 0] < pos[:, 1]).all()
    assert len({int(p) for p in pos[:, 0]}) == 1  # frozen since eviction

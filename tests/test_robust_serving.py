"""Overload-graceful serving: deadlines, cancellation, admission, faults.

The robustness contract under test —

* every request terminates with a typed ``FinishReason`` — deadline
  expiry pops queued requests and EVICTS in-flight ones mid-decode (an
  active-mask flip, zero retrace), keeping partial tokens; ``cancel`` is
  idempotent; nothing ever hangs (``run_until_drained`` watchdog);
* survivors of an eviction/cancellation are BIT-IDENTICAL to a solo
  engine serving them alone (randomized schedule vs oracle, under
  ``no_retrace``);
* submit refuses impossible work with a typed ``SubmitRejected``
  (oversized prompt, cache overflow, bad deadline), while LOAD-dependent
  refusals (bounded queue, admission policy) come back as terminal
  SHED/REJECTED statuses instead of exceptions;
* ``QualityShed`` downgrades hi->mid->lo against the SLO budget before
  shedding — the realized tier shows on the status next to the caller's
  ``requested`` tier;
* a checksum-corrupted trailing LSB plane caps the artifact's tier
  ceiling and serves BIT-IDENTICAL to (a) a truncated plane-major
  download and (b) the pristine artifact at the ceiling tier; MSB/sign
  plane corruption is a hard typed ``ArtifactIntegrityError``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs.base import ArchConfig
from repro.models.api import Model
from repro.models.base import init_params
from repro.quant.artifact import QualitySpec, QualityTier
from repro.serve import (
    FinishReason,
    QualityShed,
    QueueFullError,
    Scheduler,
    SLOBudget,
    SubmitRejected,
    faults,
)
from repro.serve.admission import ADMIT, REJECT, SHED, AdmitAll, LoadView

# lo keeps ONE plane on every packable weight (see bench_serve's
# PLANE_STREAM_TIERS): tier costs separate as ~(1, 2/3, 1/3), and any
# single-leaf LSB damage is covered by mid's full-coverage drop — the
# ceiling the corruption tests assert.
STREAM_TIERS = QualitySpec((
    QualityTier("hi", drop_planes=0, drop_frac=0.0),
    QualityTier("mid", drop_planes=1, drop_frac=1.0),
    QualityTier("lo", drop_planes=2, drop_frac=1.0),
))


def _model_and_params():
    cfg = ArchConfig(name="smollm-like", family="dense", n_layers=2,
                     d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256,
                     dtype=jnp.float32, remat=False)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_descs())
    return model, params


@pytest.fixture(scope="module")
def artifact():
    model, params = _model_and_params()
    return api.compress(model, params, tiers=STREAM_TIERS), model, params


@pytest.fixture(scope="module")
def solo_oracle(artifact):
    """(prompt, max_new, tier) -> solo tokens from a SINGLE-TIER engine
    (physically plane-truncated params — shares nothing with the
    per-slot mask path but the wire)."""
    art, _, _ = artifact
    engines = {}
    memo = {}

    def run(prompt, max_new, tier):
        key = (tuple(prompt), max_new, tier)
        if key not in memo:
            if tier not in engines:
                engines[tier] = art.engine(quality=tier, per_request=False,
                                           batch_slots=1, continuous=False)
            memo[key] = engines[tier].generate([list(prompt)],
                                               max_new=max_new)[0]
        return memo[key]

    return run


def _engine(art, slots=2, **kw):
    eng = art.engine(quality="hi", batch_slots=slots, max_prompt=8,
                     max_len=24, **kw)
    assert eng.per_request_quality
    return eng


def _warm_all_tiers(eng):
    """Trace _admit/_cont_step at every demand before a no_retrace block."""
    for q in eng.tier_names:
        eng.submit([3, 1], max_new=2, quality=q)
        eng.run_until_drained()
    eng.reset_stream()


# --------------------------------------------------------------------------
# Scheduler units: bounded queue, deadlines, cancellation (host-side)
# --------------------------------------------------------------------------
def test_scheduler_bounded_queue():
    sch = Scheduler(1, max_queue=2)
    sch.submit([1], max_new=1, arrival=0)
    sch.submit([2], max_new=1, arrival=0)
    assert sch.queue_full
    with pytest.raises(QueueFullError, match="max_queue=2"):
        sch.submit([3], max_new=1, arrival=0)
    with pytest.raises(ValueError, match="max_queue"):
        Scheduler(1, max_queue=0)


def test_scheduler_submit_validation():
    sch = Scheduler(1)
    with pytest.raises(SubmitRejected, match="at least one token"):
        sch.submit([], max_new=4, arrival=0)
    with pytest.raises(SubmitRejected, match="max_new"):
        sch.submit([1], max_new=0, arrival=0)


def test_scheduler_expire_queued_times_out():
    sch = Scheduler(1)
    r_dead = sch.submit([1, 2], max_new=4, arrival=0, deadline=5.0,
                        arrival_t=0.0)
    r_live = sch.submit([3], max_new=4, arrival=0)  # no deadline
    expired = sch.expire_queued(step=3, now=6.0)
    assert [r.rid for r in expired] == [r_dead]
    st = sch.poll(r_dead)
    assert st.finish_reason is FinishReason.TIMED_OUT
    assert st.tokens == [] and st.admitted is None
    assert st.finished_t == 6.0 and st.deadline == 5.0
    assert sch.poll(r_live).finish_reason is None
    assert sch.expire_queued(step=4, now=7.0) == []  # no double expiry


def test_scheduler_cancel_queued_live_terminal():
    sch = Scheduler(1)
    r_q = sch.submit([1], max_new=4, arrival=0)
    r_live = sch.submit([2], max_new=4, arrival=0)
    # make r_live live first (FIFO: admit r_q then cancel it from queue)
    req, slot = sch.cancel(r_q, step=0, now=0.0)
    assert req.rid == r_q and slot is None
    assert sch.poll(r_q).finish_reason is FinishReason.CANCELLED
    slot, req = next(iter(sch.admissible()))
    sch.activate(slot, req, step=1, now=1.0)
    sch.start_decoding(slot)
    sch.record(slot, 7, step=1, now=1.0)
    req2, freed = sch.cancel(r_live, step=2, now=2.0)
    assert req2.rid == r_live and freed == slot
    st = sch.poll(r_live)
    assert st.finish_reason is FinishReason.CANCELLED
    assert st.tokens == [7]  # partial result kept
    # idempotent on terminal rids; unknown rids raise
    assert sch.cancel(r_live, step=3, now=3.0) == (None, None)
    with pytest.raises(KeyError):
        sch.cancel(999, step=3, now=3.0)


def test_scheduler_finish_unadmitted_counts_not_raises():
    sch = Scheduler(1)
    rid = sch.finish_unadmitted([1, 2], max_new=4, arrival=0,
                                reason=FinishReason.SHED, quality="lo",
                                requested="hi", detail="over budget")
    st = sch.poll(rid)
    assert st.finish_reason is FinishReason.SHED
    assert st.tokens == [] and st.requested == "hi"
    assert st.detail == "over budget"
    assert not sch.has_work  # never queued, never held a slot


# --------------------------------------------------------------------------
# Engine: deadlines, cancellation, survivors bit-identical, zero retrace
# --------------------------------------------------------------------------
def test_deadline_evicts_midstream_survivor_exact(artifact, solo_oracle,
                                                  no_retrace):
    art, _, _ = artifact
    eng = _engine(art, slots=2)
    _warm_all_tiers(eng)
    p_dead, p_live = [5, 6, 7], [9, 9]
    with no_retrace(eng._cont_step, eng._admit):
        r_dead = eng.submit(p_dead, max_new=8, quality="hi", deadline=2.5)
        r_live = eng.submit(p_live, max_new=8, quality="hi")
        done = eng.run_until_drained()
    st = done[r_dead]
    assert st.finish_reason is FinishReason.TIMED_OUT
    assert 0 < len(st.tokens) < 8, "eviction must keep a PARTIAL result"
    solo = solo_oracle(p_dead, 8, "hi")
    assert st.tokens == solo[:len(st.tokens)], \
        "partial tokens must be a prefix of the solo decode"
    assert st.latency_t is not None and st.finished_t >= st.deadline
    assert done[r_live].tokens == solo_oracle(p_live, 8, "hi"), \
        "survivor of a mid-decode eviction must stay bit-identical"


def test_deadline_expires_queued_request(artifact, solo_oracle):
    art, _, _ = artifact
    eng = _engine(art, slots=1)
    r_live = eng.submit([1, 2, 3], max_new=6, quality="hi")
    r_dead = eng.submit([4, 4], max_new=6, quality="hi", deadline=3.0)
    done = eng.run_until_drained()
    assert done[r_live].ok
    assert done[r_live].tokens == solo_oracle([1, 2, 3], 6, "hi")
    st = done[r_dead]
    assert st.finish_reason is FinishReason.TIMED_OUT
    assert st.tokens == [] and st.admitted is None, \
        "a queued request must expire without ever taking a slot"


def test_cancel_midstream_survivor_exact(artifact, solo_oracle, no_retrace):
    art, _, _ = artifact
    eng = _engine(art, slots=2)
    _warm_all_tiers(eng)
    p_a, p_b = [8, 1, 6], [2, 2]
    with no_retrace(eng._cont_step, eng._admit):
        r_a = eng.submit(p_a, max_new=8, quality="hi")
        r_b = eng.submit(p_b, max_new=8, quality="hi")
        for _ in range(3):
            eng.step()
        st = eng.cancel(r_b)
        done = eng.run_until_drained()
    assert st.finish_reason is FinishReason.CANCELLED
    assert 0 < len(st.tokens) < 8
    assert st.tokens == solo_oracle(p_b, 8, "hi")[:len(st.tokens)]
    assert done[r_a].tokens == solo_oracle(p_a, 8, "hi")
    # idempotent: cancelling a terminal rid returns the same status
    again = eng.cancel(r_b)
    assert again.finish_reason is FinishReason.CANCELLED
    assert again.tokens == st.tokens
    with pytest.raises(KeyError):
        eng.cancel(12345)


def test_robust_fuzz_vs_solo_oracle(artifact, solo_oracle, no_retrace):
    """Randomized submit/step/cancel/deadline schedule across mixed tiers:
    every DONE request bit-identical to its solo oracle, every evicted one
    a prefix — with the dispatch counters frozen the whole time."""
    art, _, _ = artifact
    eng = _engine(art, slots=3)
    _warm_all_tiers(eng)
    rng = np.random.default_rng(42)
    tiers = eng.tier_names
    specs = {}  # rid -> (prompt, max_new, tier)
    with no_retrace(eng._cont_step, eng._admit):
        for _ in range(10):
            prompt = rng.integers(1, 200, size=int(rng.integers(1, 6))).tolist()
            max_new = int(rng.integers(1, 7))
            tier = tiers[int(rng.integers(0, len(tiers)))]
            deadline = float(rng.uniform(2.0, 9.0)) \
                if rng.random() < 0.3 else None
            rid = eng.submit(prompt, max_new=max_new, quality=tier,
                             deadline=deadline)
            specs[rid] = (prompt, max_new, tier)
            for _ in range(int(rng.integers(0, 3))):
                if eng.has_work:
                    eng.step()
            if rng.random() < 0.25:
                victims = [r for r in specs
                           if eng.poll(r).finish_reason is None]
                if victims:
                    eng.cancel(int(rng.choice(victims)))
        eng.run_until_drained()
    for rid, (prompt, max_new, tier) in specs.items():
        st = eng.poll(rid)
        assert st.done, f"r{rid} never terminated"
        solo = solo_oracle(prompt, max_new, tier)
        if st.ok:
            assert st.tokens == solo, f"r{rid}@{tier} diverged from solo"
        else:
            assert st.finish_reason in (FinishReason.TIMED_OUT,
                                        FinishReason.CANCELLED)
            assert st.tokens == solo[:len(st.tokens)], \
                f"r{rid}@{tier} partial tokens not a solo prefix"


# --------------------------------------------------------------------------
# Typed submit errors / watchdog — the infinite-hang class, killed
# --------------------------------------------------------------------------
def test_submit_rejects_impossible_work(artifact):
    art, _, _ = artifact
    eng = _engine(art)
    with pytest.raises(SubmitRejected, match="prefill window"):
        eng.submit(faults.oversized_prompt(eng), max_new=2)
    with pytest.raises(SubmitRejected, match="max_len"):
        eng.submit([1], max_new=10_000)
    with pytest.raises(SubmitRejected, match="deadline"):
        eng.submit([1], max_new=2, deadline=0.0)
    assert not eng.has_work, "rejected submits must leave nothing queued"
    # SubmitRejected IS a ValueError — existing except clauses keep working
    assert issubclass(SubmitRejected, ValueError)


def test_run_until_drained_watchdog(artifact):
    art, _, _ = artifact
    eng = _engine(art, slots=1)
    eng.submit([1, 2], max_new=4)
    with pytest.raises(RuntimeError, match="watchdog"):
        eng.run_until_drained(max_ticks=0)
    # the stream is still drainable afterwards — the watchdog only raises
    done = eng.run_until_drained()
    assert len(done) == 1 and next(iter(done.values())).ok


def test_engine_bounded_queue_rejects_typed(artifact):
    art, _, _ = artifact
    eng = _engine(art, slots=1, max_queue=1)
    r1 = eng.submit([1, 2], max_new=3)
    r2 = eng.submit([3], max_new=3)  # queue is now at its bound
    st = eng.poll(r2)
    assert st.finish_reason is FinishReason.REJECTED
    assert "max_queue" in st.detail and st.tokens == []
    done = eng.run_until_drained()
    assert done[r1].ok


# --------------------------------------------------------------------------
# Admission policy: downgrade before shedding, shed before timing out
# --------------------------------------------------------------------------
def _view(queued=(), live=(), slots=2):
    return LoadView(step=0, now=0.0, n_slots=slots,
                    tier_names=("hi", "mid", "lo"),
                    tier_costs=(1.0, 2 / 3, 1 / 3), queued=tuple(queued),
                    live=tuple(live))


def test_quality_shed_decide_ladder():
    p = QualityShed(SLOBudget(latency=10.0, max_queue=2))
    # idle: requested tier fits
    d = p.decide(0, 8, _view())
    assert d.action == ADMIT and d.tier == 0
    # busy (wait 4): hi estimates 12, mid 9.33 -> downgraded with a detail
    d = p.decide(0, 8, _view(live=[(0, 4)], slots=1))
    assert d.action == ADMIT and d.tier == 1 and "downgraded" in d.detail
    # saturated: even lo misses the budget -> SHED
    d = p.decide(0, 8, _view(live=[(0, 8), (0, 8)], queued=[(0, 8)],
                             slots=1))
    assert d.action == SHED and "even lo" in d.detail
    # queue depth cap -> REJECT before any estimating
    d = p.decide(2, 1, _view(queued=[(2, 1), (2, 1)]))
    assert d.action == REJECT and "cap" in d.detail
    # a lo request is never upgraded
    d = p.decide(2, 8, _view())
    assert d.action == ADMIT and d.tier == 2


def test_admit_all_is_fifo_baseline():
    d = AdmitAll().decide(1, 8, _view(queued=[(0, 8)] * 50))
    assert d.action == ADMIT and d.tier == 1


def test_quality_shed_downgrade_realized_on_engine(artifact, solo_oracle):
    art, _, _ = artifact
    eng = _engine(art, slots=1,
                  admission=QualityShed(SLOBudget(latency=4.5)))
    # idle stream, 6 dispatches: hi estimates 6.0 > 4.5, mid 4.0 fits
    rid = eng.submit([7, 7], max_new=6, quality="hi")
    st = eng.poll(rid)
    assert st.requested == "hi" and st.quality == "mid", \
        "the downgrade must be visible on the status"
    done = eng.run_until_drained()
    assert done[rid].tokens == solo_oracle([7, 7], 6, "mid"), \
        "a downgraded request is served EXACTLY at the downgraded tier"


def test_quality_shed_sheds_when_even_lo_misses(artifact):
    art, _, _ = artifact
    eng = _engine(art, slots=1,
                  admission=QualityShed(SLOBudget(latency=3.0)))
    r1 = eng.submit([1], max_new=8, quality="lo")  # 8/3 = 2.67 fits
    r2 = eng.submit([2], max_new=8, quality="hi")  # wait 2.67 + 8/3 > 3
    assert eng.poll(r1).finish_reason is None
    st = eng.poll(r2)
    assert st.finish_reason is FinishReason.SHED
    assert st.tokens == [] and "even lo" in st.detail
    eng.run_until_drained()
    assert eng.poll(r1).ok


# --------------------------------------------------------------------------
# Fault harness: replay determinism, stragglers, burst arrivals
# --------------------------------------------------------------------------
def test_replay_deterministic_and_typed(artifact):
    art, _, _ = artifact
    eng = _engine(art, slots=2)
    prompts = [[1, 2], [3], [4, 5, 6], [7]]
    trace = faults.burst_trace(len(prompts))  # thundering herd at t=0
    outcomes = []
    for _ in range(2):
        eng.reset_stream()
        rep = faults.replay(eng, prompts, trace, max_new=4, deadline=4.0)
        assert set(rep.statuses) == set(range(len(prompts)))
        assert all(st.done for st in rep.statuses.values())
        s = rep.summary()
        assert s["done_rate"] + s["timeout_rate"] + s["shed_rate"] \
            + s["reject_rate"] == pytest.approx(1.0)
        assert s["timeout_rate"] > 0, \
            "a 2-slot burst of 4 with deadline 4.0 must time someone out"
        outcomes.append({r: (st.finish_reason, tuple(st.tokens))
                         for r, st in rep.statuses.items()})
    assert outcomes[0] == outcomes[1], "replay must be deterministic"


def test_replay_slow_ticks_age_deadlines(artifact):
    art, _, _ = artifact
    eng = _engine(art, slots=2)
    prompts = [[1, 2], [3, 4]]
    healthy = faults.replay(eng, prompts, [0.0, 0.0], max_new=4,
                            deadline=6.0)
    assert all(st.ok for st in healthy.statuses.values())
    eng.reset_stream()
    # every tick stalls 3 extra cost units: deadlines age through it
    slowed = faults.replay(eng, prompts, [0.0, 0.0], max_new=4,
                           deadline=6.0, slow=faults.slow_ticks(1, 3.0))
    assert any(st.finish_reason is FinishReason.TIMED_OUT
               for st in slowed.statuses.values()), \
        "stalls must push requests past their deadline"


# --------------------------------------------------------------------------
# Degraded wire: per-plane checksums cap the tier ceiling
# --------------------------------------------------------------------------
def test_lsb_corruption_caps_tier_bit_identical(tmp_path, artifact,
                                                solo_oracle):
    art, _, _ = artifact
    clean_path = tmp_path / "model.edge.npz"
    art.save(clean_path)
    # pristine round trip: verified, undamaged, full ladder
    clean = api.load(clean_path)
    assert clean.plane_damage == {} and clean.tier_ceiling_index() == 0
    bad_path = faults.corrupt_plane_npz(clean_path, plane=2, n_flips=3,
                                        seed=1, out=tmp_path / "lsb.npz")
    damaged = api.load(bad_path)
    assert damaged.plane_damage, "checksums must catch the flipped plane"
    assert damaged.tier_ceiling_index() == 1  # mid truncates every leaf
    # partial download: the LSB planes mid's deferral schedule covers
    # never arrived (under plane-major streaming the tier ladder IS the
    # download order — only tier-deferrable planes trail)
    trunc_path = faults.truncate_planes_npz(clean_path, drop=1,
                                            leaves=art.drop_map("mid"),
                                            out=tmp_path / "trunc.npz")
    truncated = api.load(trunc_path)  # partial download IS a tier
    assert truncated.tier_ceiling_index() == 1
    prompts = [[1, 2, 3], [9, 9]]
    with pytest.warns(UserWarning, match="degraded"):
        eng_dmg = damaged.engine(quality="hi", batch_slots=2, max_prompt=8,
                                 max_len=24)
    with pytest.warns(UserWarning, match="degraded"):
        eng_trc = truncated.engine(quality="hi", batch_slots=2,
                                   max_prompt=8, max_len=24)
    for p in prompts:
        want = solo_oracle(p, 6, "mid")  # pristine artifact AT the ceiling
        assert eng_dmg.generate([p], max_new=6)[0] == want, \
            "repaired LSB damage must serve bit-identical to pristine@mid"
        assert eng_trc.generate([p], max_new=6)[0] == want, \
            "truncated download must serve bit-identical to pristine@mid"
    # damage on planes the ladder never defers cannot be served at all
    full_path = faults.truncate_planes_npz(clean_path, drop=1,
                                           out=tmp_path / "full.npz")
    with pytest.raises(api.ArtifactIntegrityError, match="exceeds"):
        api.load(full_path).tier_ceiling_index()
    # the ceiling also clamps per-request submissions upward
    rid = eng_dmg.submit(prompts[0], max_new=4, quality="hi")
    assert eng_dmg.poll(rid).quality == "mid"
    done = eng_dmg.run_until_drained()
    assert done[rid].tokens == solo_oracle(prompts[0], 4, "mid")


def test_msb_corruption_is_hard_typed_error(tmp_path, artifact):
    art, _, _ = artifact
    clean_path = tmp_path / "model.edge.npz"
    art.save(clean_path)
    bad_path = faults.corrupt_plane_npz(clean_path, plane=0, n_flips=2,
                                        seed=2, out=tmp_path / "msb.npz")
    with pytest.raises(api.ArtifactIntegrityError, match="MSB"):
        api.load(bad_path)
    # verify=False is the explicit escape hatch (load what the wire holds)
    art_unverified = api.load(bad_path, verify=False)
    assert art_unverified.plane_damage == {}

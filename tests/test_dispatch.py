"""Kernel dispatch: shape-keyed routing, tile padding, tuned-table IO.

Covers the ISSUE-3 acceptance criteria: ragged shapes go through PADDED
kernel dispatch (never the old dense-dequant materialization), config
selection is deterministic and table-overridable, and the global kernel
switch still forces the pure-XLA packed reference everywhere.
"""
import jax
import numpy as np
import pytest

from repro.core import codec
from repro.kernels import dispatch, ref
from repro.quant.store import PackedWeight, set_packed_matmul_kernel


@pytest.fixture(autouse=True)
def _clean_dispatch_state():
    dispatch.reset_counters()
    yield
    set_packed_matmul_kernel(True)
    dispatch.set_tuned_table(None)
    dispatch.reset_counters()


def _packed(k, n, g, seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * 0.05
    codes, scales = ref.qsq_quantize_ref(w, g, 4)
    return codec.pack_bitplane(codes), scales


def _pw(k, n, g, seed=0):
    planes, scales = _packed(k, n, g, seed)
    return PackedWeight(planes=planes, scales=scales, group_size=g, phi=4,
                        rest_ndim=1)


# --------------------------------------------------------------------------
# Planning
# --------------------------------------------------------------------------
def test_plan_routes_by_shape_class():
    pv = dispatch.plan(8, 2048, 2048, 64)
    pm = dispatch.plan(128, 2048, 2048, 64)
    assert pv.route == dispatch.ROUTE_GEMV
    assert pm.route == dispatch.ROUTE_GEMM
    assert dispatch.plan(1, 4096, 4096, 64).route == dispatch.ROUTE_GEMV


def test_plan_is_deterministic():
    a = [dispatch.plan(m, 2080, 300, 16) for m in (1, 8, 64)]
    b = [dispatch.plan(m, 2080, 300, 16) for m in (1, 8, 64)]
    assert a == b


def test_plan_tiles_divide_padded_shape():
    for m, k, n, g in [(3, 2080, 300, 16), (8, 96, 17, 32), (100, 4096, 777, 64),
                       (8, 1024, 64, 128), (256, 160, 96, 32)]:
        p = dispatch.plan(m, k, n, g)
        assert p.pm % p.bm == 0 and p.pn % p.bn == 0 and p.k % p.bk == 0
        assert p.pm >= m and p.pn >= n
        assert p.bk % codec.PLANE_GROUP == 0 and p.bk % g == 0


def test_plan_never_pads_k():
    # K is always a common multiple of 32 and G, so an exact K tile exists
    for k, g in [(2080, 16), (96, 24), (4096, 64), (160, 32)]:
        p = dispatch.plan(8, k, 64, g)
        assert p.k % p.bk == 0


def test_use_kernel_false_routes_to_xla_ref():
    assert dispatch.plan(8, 1024, 256, 64, use_kernel=False).route == \
        dispatch.ROUTE_XLA


def test_tuned_table_exact_key_overrides_class_default():
    backend = jax.default_backend()
    base = dispatch.plan(8, 1024, 256, 64)
    dispatch.set_tuned_table({backend: {
        dispatch.shape_key(8, 1024, 256, 64):
            {"kind": "gemv", "bm": 8, "bk": 512, "bn": 128},
    }})
    tuned = dispatch.plan(8, 1024, 256, 64)
    assert (tuned.bk, tuned.bn) == (512, 128)
    assert (tuned.bk, tuned.bn) != (base.bk, base.bn)
    # other shapes keep their class defaults
    assert dispatch.plan(8, 2048, 256, 64).bk == base.bk == \
        dispatch.plan(8, 1024, 512, 64).bk


def test_table_cannot_force_gemv_on_large_m():
    backend = jax.default_backend()
    dispatch.set_tuned_table({backend: {
        "gemm": {"kind": "gemv", "bm": 8, "bk": 1024, "bn": 256},
    }})
    assert dispatch.plan(512, 1024, 256, 64).route == dispatch.ROUTE_GEMM


def test_tuned_table_json_roundtrip(tmp_path):
    table = {
        "tpu": {
            "gemv": dispatch.TileConfig(kind="gemv", bm=8, bk=2048, bn=512),
            dispatch.shape_key(8, 4096, 4096, 64):
                {"kind": "gemv", "bm": 8, "bk": 1024, "bn": 256},
        },
        "cpu": {"gemm": {"kind": "gemm", "bm": 128, "bk": 256, "bn": 128}},
    }
    path = dispatch.save_tuned_table(table, tmp_path / "t.json")
    loaded = dispatch.load_tuned_table(path)
    assert loaded == {
        "tpu": {
            "gemv": {"kind": "gemv", "bm": 8, "bk": 2048, "bn": 512},
            "8x4096x4096g64": {"kind": "gemv", "bm": 8, "bk": 1024, "bn": 256},
        },
        "cpu": {"gemm": {"kind": "gemm", "bm": 128, "bk": 256, "bn": 128}},
    }
    # and the loaded table actually drives planning
    dispatch.set_tuned_table(loaded | {
        jax.default_backend(): loaded["tpu"],
    })
    assert dispatch.plan(8, 4096, 4096, 64).bk == 1024


def test_checked_in_table_is_valid():
    table = dispatch.load_tuned_table(dispatch.DEFAULT_TABLE_PATH)
    assert "tpu" in table and "cpu" in table
    for _backend, entries in table.items():
        for _key, cfg in entries.items():
            tc = dispatch.TileConfig(**cfg)
            assert tc.kind in ("gemv", "gemm")
            assert tc.bk % codec.PLANE_GROUP == 0


# --------------------------------------------------------------------------
# Execution: ragged shapes through padded kernels, never dense
# --------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n,g", [(8, 2080, 300, 16), (64, 2080, 300, 16)])
def test_ragged_shapes_pad_and_match_ref(m, k, n, g):
    """Acceptance: tile-ragged shapes (K=2080, N=300) go through padded
    kernel dispatch and match the XLA ref — the dense as_dense() path is
    gone (no route for it exists, and the trace counters prove which
    kernel ran)."""
    planes, scales = _packed(k, n, g)
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k))
    dispatch.reset_counters()
    out = dispatch.packed_matmul(x, planes, scales, group_size=g)
    want = ref.qsq_matmul_ref(x, planes, scales, g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-4)
    route = dispatch.ROUTE_GEMV if m <= dispatch.GEMV_M_MAX else dispatch.ROUTE_GEMM
    assert dispatch.counters[route] == 1
    assert dispatch.counters[f"{route}:padded"] == 1
    assert dispatch.counters[dispatch.ROUTE_XLA] == 0


def test_packed_weight_ragged_matmul_never_dense():
    """PackedWeight.matmul on a ragged (K=2080, N=300) weight takes the
    padded kernel path (dispatch trace), not a dense materialization."""
    g = 16
    pw = _pw(2080, 300, g)
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 2080))
    dispatch.reset_counters()
    out = pw.matmul(x)
    want = ref.qsq_matmul_ref(x, pw.planes, pw.scales, g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-4)
    assert dispatch.counters[dispatch.ROUTE_GEMV] == 1
    assert dispatch.counters[f"{dispatch.ROUTE_GEMV}:padded"] == 1
    assert sum(dispatch.counters.values()) == 2  # route + route:padded only


def test_kernel_switch_forces_xla_ref_everywhere():
    """set_packed_matmul_kernel(False) must route EVERY packed matmul to
    the pure-XLA packed reference (still no dense-weight leaf path)."""
    g = 32
    pw = _pw(256, 96, g, seed=3)
    x = jax.random.normal(jax.random.PRNGKey(4), (4, 256))
    set_packed_matmul_kernel(False)
    dispatch.reset_counters()
    out = pw.matmul(x)
    big = _pw(2080, 300, 16, seed=5)
    out2 = big.matmul(jax.random.normal(jax.random.PRNGKey(6), (128, 2080)))
    assert dispatch.counters[dispatch.ROUTE_XLA] == 2
    assert dispatch.counters[dispatch.ROUTE_GEMV] == 0
    assert dispatch.counters[dispatch.ROUTE_GEMM] == 0
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.qsq_matmul_ref(x, pw.planes, pw.scales, g)),
        rtol=2e-5, atol=2e-4)
    assert out2.shape == (128, 300)


def test_dispatch_counters_under_jit():
    """Routing happens at trace time, so jitted callers still record it."""
    g = 64
    pw = _pw(1024, 256, g, seed=7)
    x = jax.random.normal(jax.random.PRNGKey(8), (8, 1024))
    dispatch.reset_counters()
    out = jax.jit(pw.matmul)(x)
    assert dispatch.counters[dispatch.ROUTE_GEMV] == 1
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.qsq_matmul_ref(x, pw.planes, pw.scales, g)),
        rtol=2e-5, atol=2e-4)

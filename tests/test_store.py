"""WeightStore: the unified dense / qsq / packed leaf representations.

Covers the uniform leaf API (as_dense / matmul / nbits), contraction-aware
tree quantization, the lossless wire codec, the packed serving layout, and
scan-slicing of stacked packed leaves.  The round-trip property test runs
under hypothesis when installed, else over a fixed case sweep.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on the environment
    HAS_HYPOTHESIS = False

from repro.core.policy import QuantPolicy
from repro.core.qsq import QSQConfig, QSQTensor, bits_per_code, quantize
from repro.models.base import ParamDesc
from repro.quant import store


def _stacked_params():
    """A mini 'model': stacked mlp weight, wo-style weight, embedding, norm."""
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    params = {
        "wg": jax.random.normal(ks[0], (3, 64, 96)) * 0.1,   # (L, K, F)
        "wo": jax.random.normal(ks[1], (3, 4, 16, 64)) * 0.1,  # (L, h, hd, d)
        "tok": jax.random.normal(ks[2], (128, 64)) * 0.1,
        "norm": jnp.ones((64,)),
    }
    descs = {
        "wg": ParamDesc((3, 64, 96), ("layers", "embed", "mlp")),
        "wo": ParamDesc((3, 4, 16, 64), ("layers", "heads", None, "embed")),
        "tok": ParamDesc((128, 64), ("vocab", "embed")),
        "norm": ParamDesc((64,), (None,)),
    }
    return params, descs


def _policy():
    return QuantPolicy(base=QSQConfig(group_size=16, refit_alpha=True),
                       min_numel=512)


def test_quantize_tree_contraction_grouping():
    params, descs = _stacked_params()
    qt = store.quantize_tree(params, _policy(), descs)
    wg = qt["wg"]
    assert isinstance(wg, store.QSQWeight)
    assert isinstance(wg, QSQTensor)  # legacy isinstance checks keep working
    # grouped along the contraction axis (64), vmapped over the layer stack
    assert wg.scales.shape == (3, 64 // 16, 96)
    assert wg.rest_ndim == 1
    # wo: contraction spans heads x hd -> not kernel-groupable; the legacy
    # 4-D channel-major view applies and decodes back to the original shape
    assert isinstance(qt["wo"], store.QSQWeight)
    assert qt["wo"].conv_shape == (3, 4, 16, 64)
    assert qt["wo"].as_dense().shape == (3, 4, 16, 64)
    # norm excluded entirely
    assert not store.is_store(qt["norm"])


def test_uniform_leaf_api():
    params, descs = _stacked_params()
    qt = store.quantize_tree(params, _policy(), descs)
    q = qt["wg"]
    p = q.pack()
    d = store.DenseWeight(value=q.as_dense())
    for leaf in (q, p, d):
        assert leaf.as_dense().shape == (3, 64, 96)
        assert leaf.nbits() > 0
    np.testing.assert_allclose(np.asarray(p.as_dense()), np.asarray(q.as_dense()),
                               rtol=1e-6)
    # packed is ~3.5 bits/weight, dense is 32
    assert p.nbits() == q.nbits() < d.nbits() / 5


def test_packed_matmul_matches_dense_after_scan_slice():
    """Slicing the stack axis (what the layer scan does) must leave a leaf
    whose kernel matmul equals x @ as_dense exactly."""
    params, descs = _stacked_params()
    qt = store.quantize_tree(params, _policy(), descs)
    pw = qt["wg"].pack()
    layer1 = jax.tree_util.tree_map(lambda a: a[1], pw)
    assert isinstance(layer1, store.PackedWeight)
    x = jax.random.normal(jax.random.PRNGKey(7), (5, 64))
    out_k = layer1.matmul(x)
    out_d = jnp.tensordot(x, layer1.as_dense(x.dtype), axes=1)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_d),
                               rtol=2e-5, atol=2e-4)
    # stacked leaves refuse a direct matmul instead of silently misdecoding
    with pytest.raises(ValueError):
        pw.matmul(x)


def test_serve_tree_packs_only_kernel_eligible():
    params, descs = _stacked_params()
    qt = store.quantize_tree(params, _policy(), descs)
    served, n_packed = store.serve_tree(qt, descs)
    assert n_packed == 1
    assert isinstance(served["wg"], store.PackedWeight)
    # wo / tok decoded dense at load; norm untouched
    assert isinstance(served["wo"], jax.Array)
    assert served["wo"].shape == (3, 4, 16, 64)
    assert isinstance(served["tok"], jax.Array)


def test_wire_roundtrip_lossless_and_legacy_compatible():
    params, descs = _stacked_params()
    qt = store.quantize_tree(params, _policy(), descs)
    wire = store.tree_to_wire(qt)
    back = store.tree_from_wire(wire)
    for k in ("wg", "wo"):
        np.testing.assert_array_equal(np.asarray(qt[k].levels),
                                      np.asarray(back[k].levels))
        np.testing.assert_array_equal(np.asarray(qt[k].scales),
                                      np.asarray(back[k].scales))
        assert back[k].rest_ndim == (qt[k].rest_ndim
                                     if qt[k].rest_ndim is not None
                                     else qt[k].levels.ndim - 1)
    # a legacy wire dict (no rest_ndim) decodes with axis-0 grouping
    legacy = {k: v for k, v in wire["wo"].items() if k != "rest_ndim"}
    lw = store.wire_decode_leaf(legacy)
    np.testing.assert_allclose(np.asarray(lw.as_dense()),
                               np.asarray(qt["wo"].as_dense()))


def test_bits_report_counts_packed_leaves():
    params, descs = _stacked_params()
    qt = store.quantize_tree(params, _policy(), descs)
    served, _ = store.serve_tree(qt, descs)
    rep = store.tree_bits_report(served)
    assert rep["n_store_leaves"] == 1
    assert rep["n_leaves"] == 4
    assert 0 < rep["savings"] < 1


def _check_leaf_roundtrip(seed, phi, log_g, stack):
    """quantize -> pack -> wire -> unpack -> pack must be lossless."""
    g = 2 ** log_g
    k = max(32, 4 * g)
    shape = (2,) * stack + (k, 8)
    w = jax.random.normal(jax.random.PRNGKey(seed), shape) * 0.2

    def enc(w2):
        q = quantize(w2, QSQConfig(phi=phi, group_size=g))
        return q.levels, q.scales

    fn = enc
    for _ in range(stack):
        fn = jax.vmap(fn)
    levels, scales = fn(w)
    q = store.QSQWeight(levels=levels, scales=scales, group_size=g, phi=phi,
                        rest_ndim=1)
    back = store.wire_decode_leaf(store.wire_encode_leaf(q))
    np.testing.assert_array_equal(np.asarray(back.levels), np.asarray(q.levels))
    np.testing.assert_array_equal(np.asarray(back.scales), np.asarray(q.scales))
    p2 = back.pack().unpack()
    np.testing.assert_array_equal(np.asarray(p2.levels), np.asarray(q.levels))
    np.testing.assert_allclose(np.asarray(back.as_dense()),
                               np.asarray(q.as_dense()))
    assert q.nbits() == bits_per_code(phi) * levels.size + 32 * scales.size


if HAS_HYPOTHESIS:

    @settings(deadline=None, max_examples=25)
    @given(seed=st.integers(0, 2**31 - 1), phi=st.sampled_from([1, 2, 4]),
           log_g=st.integers(0, 5), stack=st.integers(0, 2))
    def test_property_store_roundtrip(seed, phi, log_g, stack):
        _check_leaf_roundtrip(seed, phi, log_g, stack)

else:

    @pytest.mark.parametrize("seed,phi,log_g,stack", [
        (0, 4, 4, 0), (1, 4, 0, 1), (2, 2, 3, 2), (3, 1, 5, 1),
    ])
    def test_property_store_roundtrip(seed, phi, log_g, stack):
        _check_leaf_roundtrip(seed, phi, log_g, stack)

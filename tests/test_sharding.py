"""Sharding rules + partition specs + jitted train step under a debug mesh."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.launch.mesh import make_debug_mesh, mesh_axis_sizes, sharding_rules
from repro.models import Model
from repro.models.base import ParamDesc, init_params, partition_specs, spec_for_shape


RULES = {"batch": ("data",), "heads": ("model",), "mlp": ("model",),
         "vocab": ("model",), "embed": ("data",), "experts": ("model",)}
SIZES = {"data": 4, "model": 8}


def test_spec_basic():
    d = ParamDesc((64, 32), ("embed", "mlp"))
    assert spec_for_shape(d.shape, d.axes, RULES, SIZES) == P("data", "model")


def test_spec_divisibility_fallback():
    # 9 heads not divisible by model=8 -> replicated (the smollm case)
    assert spec_for_shape((64, 9, 8), ("embed", "heads", None), RULES, SIZES) \
        == P("data", None, None)


def test_spec_axis_used_once():
    # both dims map to "model": only the first gets it
    assert spec_for_shape((32, 64), ("mlp", "vocab"), RULES, SIZES) \
        == P("model", None)


def test_spec_multi_axis_product():
    rules = {"batch": ("pod", "data")}
    sizes = {"pod": 2, "data": 4, "model": 8}
    assert spec_for_shape((32, 16), ("batch", None), rules, sizes) \
        == P(("pod", "data"), None)
    # not divisible by 8 -> replicated
    assert spec_for_shape((12, 16), ("batch", None), rules, sizes) == P(None, None)


def test_production_rules_cover_all_model_axes():
    mesh = make_debug_mesh(1, 1)
    rules = sharding_rules(mesh)
    for name in ("batch", "vocab", "heads", "kv_heads", "mlp", "experts",
                 "heads_inner", "seq_kv", "embed"):
        assert name in rules


def test_partition_specs_whole_model():
    cfg = get_arch("deepseek_7b")  # full config, abstract only
    model = Model(cfg)
    descs = model.param_descs()
    specs = partition_specs(descs, RULES, SIZES)
    flat = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P)
    )
    assert all(isinstance(s, P) for s in flat)
    # the mlp weight must actually be 2-D sharded (leading scan-stacked
    # layers dim replicated)
    blocks = specs["blocks"]
    assert blocks["mlp"]["wg"] == P(None, "data", "model")


def test_train_step_jitted_on_debug_mesh():
    """End-to-end pjit on a 1x1 mesh (single CPU device) with real shardings."""
    from jax.sharding import NamedSharding

    from repro.train.state import train_state_descs
    from repro.train.step import make_train_step

    cfg = get_arch("deepseek_7b", smoke=True)
    model = Model(cfg)
    mesh = make_debug_mesh(1, 1)
    rules = sharding_rules(mesh, fsdp=False)
    sizes = mesh_axis_sizes(mesh)

    sd = train_state_descs(model)
    spec = partition_specs(sd, rules, sizes)
    shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec,
        is_leaf=lambda x: isinstance(x, P),
    )
    state = init_params(jax.random.PRNGKey(0), sd)
    state = jax.device_put(state, shardings)
    step = jax.jit(make_train_step(model), in_shardings=(shardings, None),
                   out_shardings=(shardings, None), donate_argnums=(0,))
    tok = jnp.zeros((2, 16), jnp.int32)
    with mesh:
        state2, metrics = step(state, {"tokens": tok, "labels": tok})
    assert np.isfinite(float(metrics["loss"]))


def test_make_production_mesh_requires_512_devices():
    """On this 1-device process the production mesh must refuse to build —
    documents that only launch/dryrun.py (512 placeholder devices) builds it."""
    import pytest

    from repro.launch.mesh import make_production_mesh

    if jax.device_count() >= 256:  # pragma: no cover
        pytest.skip("running inside a many-device process")
    with pytest.raises(ValueError):
        make_production_mesh()

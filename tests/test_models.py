"""Per-architecture smoke tests (reduced same-family configs, one forward +
one train step on CPU, shape + no-NaN asserts) and decode/forward consistency.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.models import Model
from repro.models.base import init_params
from repro.optim import AdamWConfig, adamw_init_descs, adamw_update


def _batch_for(cfg, b, s, key):
    tok = jax.random.randint(key, (b, s), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": tok}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            key, (b, cfg.vision_tokens, cfg.d_model), cfg.dtype) * 0.1
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (b, cfg.enc_seq, cfg.d_model), cfg.dtype) * 0.1
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch_id):
    cfg = get_arch(arch_id, smoke=True)
    model = Model(cfg)
    key = jax.random.PRNGKey(0)
    params = init_params(key, model.param_descs())
    b, s = 2, 32
    batch = _batch_for(cfg, b, s, key)

    logits = model.forward(params, batch)
    assert logits.shape == (b, s, cfg.vocab)
    assert not bool(jnp.isnan(logits).any()), "NaN in forward logits"

    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    opt = init_params(key, adamw_init_descs(model.param_descs()))
    new_params, opt2, gnorm = adamw_update(AdamWConfig(), params, grads, opt)
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0
    loss2 = model.loss(new_params, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_matches_forward(arch_id):
    """Teacher-forced step-by-step decode must reproduce the full forward
    logits (validates KV caches, ring buffers, SSM decode states)."""
    cfg = get_arch(arch_id, smoke=True)
    model = Model(cfg)
    key = jax.random.PRNGKey(1)
    params = init_params(key, model.param_descs())
    b, s = 2, 12
    batch = _batch_for(cfg, b, s, key)
    full = model.forward(params, batch)  # (b, s, V)

    cache = init_params(key, model.cache_descs(b, s + 1))
    if cfg.family == "vlm":
        from repro.models.transformer import LMCache, vision_prefill_cross_kv

        ckv = vision_prefill_cross_kv(params, cfg, batch["vision_embeds"])
        cache = LMCache(kv=cache.kv, cross_kv=ckv)
    if cfg.family == "encdec":
        from repro.models.encdec import EncDecCache, encdec_prefill_cross

        ck, cv = encdec_prefill_cross(params, cfg, batch["frames"])
        cache = EncDecCache(kv=cache.kv, cross_k=ck, cross_v=cv)

    outs = []
    for t in range(s):
        logits, cache = model.decode(
            params, cache, {"tokens": batch["tokens"][:, t : t + 1]}
        )
        outs.append(logits[:, 0])
    step = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(step), np.asarray(full), rtol=5e-2, atol=5e-2
    )


def test_swa_ring_buffer_decode():
    """With window < cache length the ring buffer must drop old tokens:
    decoding the same suffix after different prefixes converges."""
    cfg = get_arch("mixtral_8x22b", smoke=True)  # window=32
    model = Model(cfg)
    key = jax.random.PRNGKey(2)
    params = init_params(key, model.param_descs())

    def run(prefix_tokens):
        cache = init_params(key, model.cache_descs(1, 120))
        logits = None
        for t in prefix_tokens:
            logits, cache = model.decode(
                params, cache, {"tokens": jnp.array([[t]], jnp.int32)}
            )
        return logits

    # SWA context propagates window tokens PER LAYER (the Mistral
    # "effective context = layers x window" effect), so full convergence
    # needs > n_layers * window suffix tokens: 2 * 32 = 64 here.
    suffix = list(range(70))
    la = run([1, 2, 3] + suffix)
    lb = run([9, 8, 7] + suffix)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb), rtol=1e-4, atol=1e-4)


def test_cnn_forward_shapes():
    from repro.models.cnn import CONVNET4, LENET, cnn_descs, cnn_forward

    key = jax.random.PRNGKey(0)
    for cfg in (LENET, CONVNET4):
        params = init_params(key, cnn_descs(cfg))
        x = jax.random.normal(key, (4, *cfg.input_hw, cfg.input_c))
        logits = cnn_forward(params, cfg, x)
        assert logits.shape == (4, cfg.n_classes)
        assert not bool(jnp.isnan(logits).any())


def test_attention_chunked_equals_dense():
    """The q-chunked long-seq path must equal single-shot attention."""
    from repro.models import layers as L

    key = jax.random.PRNGKey(3)
    d, h, kv, hd = 32, 4, 2, 8
    p = init_params(key, L.attn_descs(d, h, kv, hd))
    x = jax.random.normal(key, (2, 64, d)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(64), (2, 64))
    dense = L.attention(p, x, positions=pos, q_chunk=64)
    chunked = L.attention(p, x, positions=pos, q_chunk=16)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               rtol=1e-4, atol=1e-4)


def test_swa_sliced_path_equals_masked():
    """The sliding-window kv-sliced path == full attention w/ window mask."""
    from repro.models import layers as L

    key = jax.random.PRNGKey(4)
    d, h, kv, hd, w = 32, 4, 2, 8, 16
    p = init_params(key, L.attn_descs(d, h, kv, hd))
    x = jax.random.normal(key, (2, 128, d)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(128), (2, 128))
    ref_out = L.attention(p, x, positions=pos, window=w, q_chunk=128)
    sliced = L.attention(p, x, positions=pos, window=w, q_chunk=32)
    np.testing.assert_allclose(np.asarray(ref_out), np.asarray(sliced),
                               rtol=1e-4, atol=1e-4)


def test_moe_capacity_drops_gracefully():
    from repro.models import layers as L

    key = jax.random.PRNGKey(5)
    d, ff, e = 16, 32, 4
    p = init_params(key, L.moe_descs(d, ff, e))
    x = jax.random.normal(key, (2, 8, d))
    y, aux = L.moe(p, x, top_k=2, capacity_factor=0.25)  # tiny capacity
    assert y.shape == x.shape
    assert np.isfinite(float(aux))
    assert not bool(jnp.isnan(y).any())

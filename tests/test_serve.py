"""Serving engine: batched generation, wire-checkpoint loading."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core.policy import QuantPolicy
from repro.core.qsq import QSQConfig
from repro.models import Model
from repro.models.base import init_params
from repro.quant import pack_pytree_wire, quantize_pytree
from repro.serve import ServeConfig, ServeEngine


def _model_and_params(arch="deepseek_7b"):
    cfg = get_arch(arch, smoke=True)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_descs())
    return model, params


def test_generate_batched():
    model, params = _model_and_params()
    eng = ServeEngine(model, params, ServeConfig(batch_slots=4))
    outs = eng.generate([[1, 2, 3], [4, 5]], max_new=8)
    assert len(outs) == 2
    assert all(len(o) == 8 for o in outs)
    assert all(0 <= t < model.cfg.vocab for o in outs for t in o)


def test_generate_deterministic():
    model, params = _model_and_params()
    eng = ServeEngine(model, params, ServeConfig(batch_slots=2))
    a = eng.generate([[1, 2, 3]], max_new=6)
    b = eng.generate([[1, 2, 3]], max_new=6)
    assert a == b


def test_generate_prompt_isolation():
    """Outputs for a prompt must not depend on other slots' prompts."""
    model, params = _model_and_params()
    eng = ServeEngine(model, params, ServeConfig(batch_slots=4))
    solo = eng.generate([[1, 2, 3]], max_new=5)[0]
    pair = eng.generate([[1, 2, 3], [9, 9, 9]], max_new=5)[0]
    assert solo == pair


def test_generate_mixed_length_batch_isolation():
    """A prompt's output tokens must be EXACTLY invariant to the other
    prompts in its batch, including batches of different prompt lengths:
    left-pad positions are masked out of the one-dispatch prefill, so pad
    tokens cannot pollute the KV cache/attention of shorter prompts."""
    model, params = _model_and_params()
    eng = ServeEngine(model, params, ServeConfig(batch_slots=4))
    solo = eng.generate([[1, 2, 3]], max_new=6)[0]
    with_short = eng.generate([[1, 2, 3], [9]], max_new=6)[0]
    with_long = eng.generate(
        [[7, 7, 7, 7, 7, 7, 7, 1, 2, 3], [1, 2, 3], [42]], max_new=6
    )[1]
    assert solo == with_short == with_long


def test_prefill_one_dispatch_matches_per_token_decode():
    """The full-sequence prefill must prime the cache exactly like feeding
    the prompt token-by-token through decode (no padding involved)."""
    model, params = _model_and_params()
    toks = jnp.array([[5, 1, 2, 9, 4, 3], [8, 8, 1, 2, 7, 7]], jnp.int32)
    b, s = toks.shape

    cache = init_params(jax.random.PRNGKey(0), model.cache_descs(b, s + 4))
    fused_cache, fused_logits = model.prefill(params, cache, toks)

    step_cache = init_params(jax.random.PRNGKey(0), model.cache_descs(b, s + 4))
    logits = None
    for t in range(s):
        logits, step_cache = model.decode(
            params, step_cache, {"tokens": toks[:, t:t + 1]}
        )
    np.testing.assert_allclose(np.asarray(fused_logits),
                               np.asarray(logits[:, -1]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(fused_cache.kv.pos),
                               np.asarray(step_cache.kv.pos))
    np.testing.assert_allclose(np.asarray(fused_cache.kv.k),
                               np.asarray(step_cache.kv.k),
                               rtol=2e-4, atol=2e-4)
    # and decoding onward from either cache picks the same next token
    a, _ = model.decode(params, fused_cache, {"tokens": toks[:, :1]})
    c, _ = model.decode(params, step_cache, {"tokens": toks[:, :1]})
    np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                               rtol=2e-4, atol=2e-4)


def test_scan_prefill_families_still_generate():
    """Recurrent families keep the scanned prefill behind the same
    4-arg prefill signature."""
    model, params = _model_and_params("mamba2_1_3b")
    cache = init_params(jax.random.PRNGKey(0), model.cache_descs(2, 8))
    toks = jnp.array([[1, 2, 3], [4, 5, 6]], jnp.int32)
    cache, logits = model.prefill(params, cache, toks,
                                  jnp.array([3, 3], jnp.int32))
    assert logits.shape == (2, model.cfg.vocab)


def test_serve_from_wire_close_to_exact():
    """Engine loaded from the 3-bit wire artifact produces the same shape of
    results and close logits behaviour (greedy tokens may differ on ties,
    so compare the decoded weights' effect via loss)."""
    model, params = _model_and_params()
    qp = quantize_pytree(
        params, QuantPolicy(base=QSQConfig(group_size=16), min_numel=256)
    )
    wire = pack_pytree_wire(qp)
    eng = ServeEngine.from_wire(model, wire, ServeConfig(batch_slots=2))
    outs = eng.generate([[1, 2, 3]], max_new=4)
    assert len(outs[0]) == 4
    # decoded params give finite loss in-family
    tok = jnp.zeros((2, 8), jnp.int32)
    loss = float(model.loss(eng.params, {"tokens": tok, "labels": tok}))
    assert np.isfinite(loss)


def test_mamba_engine():
    model, params = _model_and_params("mamba2_1_3b")
    eng = ServeEngine(model, params, ServeConfig(batch_slots=2))
    outs = eng.generate([[3, 1]], max_new=4)
    assert len(outs[0]) == 4


def _smollm_class_model():
    """smollm_135m-class dense config with 32-aligned dims so the qsq_matmul
    kernel can serve every matmul weight packed (the smoke config's d=48 is
    not plane-aligned)."""
    from repro.configs.base import ArchConfig

    cfg = ArchConfig(name="smollm-like", family="dense", n_layers=2,
                     d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256,
                     dtype=jnp.float32, remat=False)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_descs())
    return model, params


def test_packed_engine_tokens_match_dense_exactly():
    """Acceptance: ServeEngine.from_wire with packed leaves (Pallas
    interpret mode on CPU) emits EXACTLY the tokens of the engine that
    dense-dequantized the same wire."""
    model, params = _smollm_class_model()
    wire = pack_pytree_wire(quantize_pytree(
        params,
        QuantPolicy(base=QSQConfig(group_size=16, refit_alpha=True), min_numel=512),
        model.param_descs(),
    ))
    eng_packed = ServeEngine.from_wire(model, wire, ServeConfig(batch_slots=4))
    eng_dense = ServeEngine.from_wire(
        model, wire, ServeConfig(batch_slots=4, packed=False)
    )
    # the packed engine really holds bit-planes, not a dequantized tree
    from repro.quant.store import PackedWeight

    assert eng_packed.n_packed_leaves >= 7
    assert isinstance(eng_packed.params["blocks"]["mlp"]["wg"], PackedWeight)
    assert isinstance(eng_packed.params["embed"]["head"], PackedWeight)
    assert eng_dense.n_packed_leaves == 0

    prompts = [[1, 2, 3], [9, 9], [100, 42, 7, 8]]
    out_p = eng_packed.generate(prompts, max_new=16)
    out_d = eng_dense.generate(prompts, max_new=16)
    assert out_p == out_d


def test_wire_export_load_serve_roundtrip(tmp_path):
    """Checkpoint wire export -> load_wire -> packed engine, losslessly."""
    from repro.checkpoint.manager import CheckpointConfig, CheckpointManager

    model, params = _smollm_class_model()
    policy = QuantPolicy(base=QSQConfig(group_size=16), min_numel=512)
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path / "w"),
                                             async_save=False))
    mgr.export_wire(params, policy, descs=model.param_descs())
    wire = mgr.load_wire()

    eng_disk = ServeEngine.from_wire(model, wire, ServeConfig(batch_slots=2))
    in_memory = pack_pytree_wire(quantize_pytree(params, policy,
                                                 model.param_descs()))
    eng_mem = ServeEngine.from_wire(model, in_memory, ServeConfig(batch_slots=2))
    assert eng_disk.n_packed_leaves == eng_mem.n_packed_leaves > 0
    assert (eng_disk.generate([[5, 6, 7]], max_new=8)
            == eng_mem.generate([[5, 6, 7]], max_new=8))

"""Shared test fixtures.

NOTE: do NOT set XLA_FLAGS / device-count overrides here — smoke tests and
benches must see the real single CPU device.  Only launch/dryrun.py (its own
process) forces 512 placeholder devices.
"""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)

"""Shared test fixtures.

NOTE: do NOT set XLA_FLAGS / device-count overrides here — smoke tests and
benches must see the real single CPU device.  Only launch/dryrun.py (its own
process) forces 512 placeholder devices.
"""
import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture
def no_retrace():
    """The no_retrace() context manager from repro.analysis.retrace.

    ``with no_retrace(eng._cont_step, eng._admit): ...`` asserts that
    the block grows no jit cache and moves no dispatch counter — the
    shared trace-once assertion for scheduler/per-request/plane-stream
    tests (QSQ002/QSQ003 argue the same thing statically).
    """
    from repro.analysis.retrace import no_retrace as _no_retrace

    return _no_retrace

"""CSD rounding / digit-count tests (the Quality Scalable Multiplier numerics).

Property tests use hypothesis when available, otherwise a fixed seed sweep.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on the environment
    HAS_HYPOTHESIS = False

from repro.core import csd


def test_known_digit_counts():
    # 0.75 = 1 - 0.25 (2 digits); 0.5 = 1 digit; 1.25 = 1 + 0.25 (2);
    # -0.375 = -0.5 + 0.125 (2); 100 = 128 - 32 + 4 (3)
    x = jnp.array([0.75, 0.5, 1.25, -0.375, 100.0])
    np.testing.assert_array_equal(np.asarray(csd.csd_digit_count(x)), [2, 1, 2, 2, 3])


def test_powers_of_two_exact():
    x = jnp.array([0.25, 0.5, 1.0, 2.0, 8.0, -4.0])
    out = csd.csd_round(x, max_digits=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(csd.csd_digit_count(x)), [1] * 6)


def _check_error_decreases_with_digits(seed, k):
    w = jax.random.normal(jax.random.PRNGKey(seed), (128,)) * 0.5
    e_k = float(jnp.sum((w - csd.csd_round(w, k)) ** 2))
    e_k1 = float(jnp.sum((w - csd.csd_round(w, k + 1)) ** 2))
    assert e_k1 <= e_k + 1e-9


def _check_relative_error_bound(seed):
    w = jax.random.uniform(jax.random.PRNGKey(seed), (128,), minval=1e-3, maxval=100.0)
    out = np.asarray(csd.csd_round(w, 1))
    rel = np.abs(out - np.asarray(w)) / np.asarray(w)
    assert (rel <= 1.0 / 3.0 + 1e-6).all()


if HAS_HYPOTHESIS:

    @settings(deadline=None, max_examples=30)
    @given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 6))
    def test_property_error_decreases_with_digits(seed, k):
        """Truncating fewer partial products can only reduce the error."""
        _check_error_decreases_with_digits(seed, k)

    @settings(deadline=None, max_examples=30)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_property_relative_error_bound(seed):
        """1-digit CSD rounding is within 33% relative error (nearest PoT)."""
        _check_relative_error_bound(seed)

else:

    @pytest.mark.parametrize("seed,k", [(0, 1), (1, 2), (2, 4), (3, 6)])
    def test_property_error_decreases_with_digits(seed, k):
        _check_error_decreases_with_digits(seed, k)

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_property_relative_error_bound(seed):
        _check_relative_error_bound(seed)


def test_partial_product_savings_range():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 64)) * 0.1
    for k in (1, 2, 4, 8):
        s = float(csd.partial_product_savings(w, k))
        assert 0.0 <= s <= 1.0
    # k=1 saves more than k=8
    assert float(csd.partial_product_savings(w, 1)) >= float(
        csd.partial_product_savings(w, 8)
    )


def test_histogram_fig11():
    """Most trained-scale weights need few CSD digits (paper Fig. 11)."""
    w = jax.random.normal(jax.random.PRNGKey(1), (256, 256)) * 0.05
    hist = np.asarray(csd.csd_nonzero_histogram(w))
    assert hist.sum() == 256 * 256
    # bulk of mass within <= 8 nonzero digits at 16 frac bits
    assert hist[:9].sum() > 0.9 * hist.sum()

"""Bit-packing roundtrips (dense wire format + bit-plane kernel format).

Property tests run under hypothesis when it is installed; on a clean
interpreter they fall back to a fixed seed sweep of the same checks so the
suite still collects and covers the codec.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on the environment
    HAS_HYPOTHESIS = False

from repro.core import codec


def _check_dense_roundtrip(n, seed, bits):
    rng = np.random.RandomState(seed)
    codes = jnp.asarray(rng.randint(0, 2**bits, size=n).astype(np.uint8))
    packed = codec.pack_dense(codes, bits=bits)
    assert packed.dtype == jnp.int32
    out = codec.unpack_dense(packed, n, bits=bits)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))


def _check_bitplane_roundtrip(kmul, n, seed):
    k = 32 * kmul
    rng = np.random.RandomState(seed)
    codes = jnp.asarray(rng.randint(0, 7, size=(k, n)).astype(np.uint8))
    planes = codec.pack_bitplane(codes)
    assert planes.shape == (k // 32, 3, n)
    out = codec.unpack_bitplane(planes)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))


if HAS_HYPOTHESIS:

    @settings(deadline=None, max_examples=30)
    @given(n=st.integers(1, 400), seed=st.integers(0, 2**31 - 1),
           bits=st.sampled_from([2, 3]))
    def test_dense_roundtrip(n, seed, bits):
        _check_dense_roundtrip(n, seed, bits)

    @settings(deadline=None, max_examples=20)
    @given(kmul=st.integers(1, 8), n=st.integers(1, 33),
           seed=st.integers(0, 2**31 - 1))
    def test_bitplane_roundtrip(kmul, n, seed):
        _check_bitplane_roundtrip(kmul, n, seed)

else:

    @pytest.mark.parametrize("n,seed,bits", [
        (1, 0, 3), (9, 1, 3), (10, 2, 3), (11, 3, 3), (400, 4, 3),
        (1, 5, 2), (16, 6, 2), (17, 7, 2), (400, 8, 2),
    ])
    def test_dense_roundtrip(n, seed, bits):
        _check_dense_roundtrip(n, seed, bits)

    @pytest.mark.parametrize("kmul,n,seed", [
        (1, 1, 0), (1, 33, 1), (3, 7, 2), (8, 32, 3),
    ])
    def test_bitplane_roundtrip(kmul, n, seed):
        _check_bitplane_roundtrip(kmul, n, seed)


def test_bitplane_requires_multiple_of_32():
    with pytest.raises(ValueError):
        codec.pack_bitplane(jnp.zeros((33, 4), jnp.uint8))


def test_wire_bytes():
    # 100 codes @3b -> 10 words -> 40 bytes; 10 scales -> 40 bytes
    assert codec.wire_bytes(100, 10, bits=3) == 40 + 40
    # 2-bit: 16/word -> ceil(100/16)=7 words
    assert codec.wire_bytes(100, 10, bits=2) == 28 + 40


def test_dense_packing_density():
    """3-bit format must actually achieve ~3.2 bits/element at scale."""
    n = 10_000
    codes = jnp.zeros(n, jnp.uint8)
    packed = codec.pack_dense(codes)
    bits_per = packed.size * 32 / n
    assert bits_per < 3.3

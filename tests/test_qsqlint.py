"""qsqlint: each rule fires on a seeded violation at the right line,
pragmas and allowlists suppress, and the repo itself lints clean.

Snippets are written to tmp_path under paths that exercise the default
config (hot paths under src/repro/..., the dispatch module's own
counter-helper exemptions), then linted with ``lint_paths`` rooted at
the tmp dir.
"""
import textwrap
from pathlib import Path

import pytest

from repro.analysis import Config, lint_paths
from repro.analysis.__main__ import main as qsqlint_main

REPO_ROOT = Path(__file__).resolve().parents[1]


def write(root: Path, rel: str, source: str) -> Path:
    path = root / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(source))
    return path


def lint(root: Path, *rels: str, config: Config | None = None):
    return lint_paths(list(rels), config=config or Config(), root=root)


def hits(violations, rule: str):
    return [v for v in violations if v.rule == rule]


# --------------------------------------------------------------------------
# QSQ001 no-dense-hot-path
# --------------------------------------------------------------------------
def test_qsq001_dense_call_on_hot_path_flagged_at_line(tmp_path):
    write(tmp_path, "src/repro/serve/hot.py", """\
        def forward(p, x):
            w = p.as_dense()
            return w @ x
        """)
    vs = hits(lint(tmp_path, "src"), "QSQ001")
    assert [(v.line, v.qualname) for v in vs] == [(2, "forward")]
    assert "as_dense" in vs[0].message


def test_qsq001_cold_path_not_flagged(tmp_path):
    write(tmp_path, "tools/export.py", """\
        def export(p):
            return p.as_dense()
        """)
    assert not hits(lint(tmp_path, "tools"), "QSQ001")


# --------------------------------------------------------------------------
# QSQ002 tracer-leak
# --------------------------------------------------------------------------
def test_qsq002_leaks_in_jitted_body_flagged_at_lines(tmp_path):
    write(tmp_path, "src/mod.py", """\
        import jax
        import numpy as np

        @jax.jit
        def leaky(x):
            if x > 0:
                x = x + 1
            y = float(x)
            z = np.sum(x)
            return y + z + x.item()
        """)
    lines = sorted(v.line for v in hits(lint(tmp_path, "src"), "QSQ002"))
    assert lines == [6, 8, 9, 10]


def test_qsq002_static_projections_do_not_taint(tmp_path):
    write(tmp_path, "src/mod.py", """\
        import jax

        @jax.jit
        def shapely(x, tiers=None):
            m, k = x.shape
            if m > k:
                x = x.reshape(k, m)
            if tiers is not None:
                x = x * 2
            n = int(x.ndim)
            return x, len(x.shape), n
        """)
    assert not hits(lint(tmp_path, "src"), "QSQ002")


def test_qsq002_static_args_are_untainted(tmp_path):
    write(tmp_path, "src/mod.py", """\
        import functools

        import jax

        @functools.partial(jax.jit, static_argnames=("mode",))
        def dispatch(x, mode):
            if mode == "fast":
                return x * 2
            return x
        """)
    assert not hits(lint(tmp_path, "src"), "QSQ002")


def test_qsq002_scan_body_checked(tmp_path):
    write(tmp_path, "src/mod.py", """\
        import jax

        def outer(xs):
            def body(carry, x):
                if x > 0:
                    carry = carry + x
                return carry, x
            return jax.lax.scan(body, 0.0, xs)
        """)
    vs = hits(lint(tmp_path, "src"), "QSQ002")
    assert [v.line for v in vs] == [5]


def test_qsq002_factory_inner_jitted_cross_module(tmp_path):
    write(tmp_path, "src/steps.py", """\
        def make_step(model):
            def step(params, x):
                return float(x) + 1
            return step
        """)
    write(tmp_path, "src/engine.py", """\
        import jax

        from steps import make_step

        def build(model):
            return jax.jit(make_step(model))
        """)
    vs = hits(lint(tmp_path, "src"), "QSQ002")
    assert [(v.path, v.line) for v in vs] == [("src/steps.py", 3)]


# --------------------------------------------------------------------------
# QSQ003 static-arg discipline
# --------------------------------------------------------------------------
def test_qsq003_factory_jit_missing_static_flagged_at_site(tmp_path):
    write(tmp_path, "src/steps.py", """\
        def make_decode(model):
            def step(params, cache, cur, demand=0):
                return params, demand
            return step
        """)
    write(tmp_path, "src/engine.py", """\
        import jax

        from steps import make_decode

        def build(model):
            return jax.jit(make_decode(model))
        """)
    vs = hits(lint(tmp_path, "src"), "QSQ003")
    assert [(v.path, v.line) for v in vs] == [("src/engine.py", 6)]
    assert "demand" in vs[0].message and "3" in vs[0].message


def test_qsq003_factory_jit_with_static_argnums_clean(tmp_path):
    write(tmp_path, "src/steps.py", """\
        def make_decode(model):
            def step(params, cache, cur, demand=0):
                return params, demand
            return step
        """)
    write(tmp_path, "src/engine.py", """\
        import jax

        from steps import make_decode

        def build(model):
            return jax.jit(make_decode(model), static_argnums=(3,))
        """)
    assert not hits(lint(tmp_path, "src"), "QSQ003")


def test_qsq003_never_static_names_rejected(tmp_path):
    write(tmp_path, "src/steps.py", """\
        def make_decode(model):
            def step(params, plane_mask, x):
                return x
            return step
        """)
    write(tmp_path, "src/engine.py", """\
        import jax

        from steps import make_decode

        def build(model):
            return jax.jit(make_decode(model),
                           static_argnames=("plane_mask",))
        """)
    vs = hits(lint(tmp_path, "src"), "QSQ003")
    assert len(vs) == 1 and "plane_mask" in vs[0].message


def test_qsq003_decorated_def_missing_static(tmp_path):
    write(tmp_path, "src/mod.py", """\
        import jax

        @jax.jit
        def step(params, demand):
            return params
        """)
    vs = hits(lint(tmp_path, "src"), "QSQ003")
    assert [v.line for v in vs] == [4]


# --------------------------------------------------------------------------
# QSQ004 kernel purity
# --------------------------------------------------------------------------
def test_qsq004_closure_and_module_captures_flagged(tmp_path):
    write(tmp_path, "src/kern.py", """\
        import functools

        import jax
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        TABLE = jnp.arange(8)

        def _kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...] + TABLE

        def run(x):
            scale = jnp.float32(2.0)

            def _inner(x_ref, o_ref):
                o_ref[...] = x_ref[...] * scale

            k = functools.partial(_kernel)
            a = pl.pallas_call(
                k, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype))(x)
            b = pl.pallas_call(
                _inner, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype))(x)
            return a + b
        """)
    vs = hits(lint(tmp_path, "src"), "QSQ004")
    messages = {v.line: v.message for v in vs}
    assert 10 in messages and "module-level array" in messages[10]
    assert 16 in messages and "closes over" in messages[16]


def test_qsq004_dynamic_blockspec_shape_flagged(tmp_path):
    write(tmp_path, "src/kern.py", """\
        from jax.experimental import pallas as pl

        def specs(n):
            return pl.BlockSpec((min(n, 8), 128), lambda i, j: (i, j))
        """)
    vs = hits(lint(tmp_path, "src"), "QSQ004")
    assert len(vs) == 1 and vs[0].line == 4
    assert "call" in vs[0].message


def test_qsq004_static_shapes_clean(tmp_path):
    write(tmp_path, "src/kern.py", """\
        import jax.numpy as jnp
        from jax.experimental import pallas as pl
        from jax.experimental.pallas import tpu as pltpu

        def specs(x, bm):
            m, _ = x.shape
            return (pl.BlockSpec((bm, m), lambda i, j: (i, j)),
                    pltpu.VMEM((m, 128), jnp.float32))
        """)
    assert not hits(lint(tmp_path, "src"), "QSQ004")


# --------------------------------------------------------------------------
# QSQ005 trace-time counters
# --------------------------------------------------------------------------
def test_qsq005_mutation_outside_dispatch_flagged(tmp_path):
    write(tmp_path, "src/mod.py", """\
        from repro.kernels import dispatch

        def sneaky():
            dispatch.counters["x"] += 1
            dispatch.traffic.clear()
        """)
    lines = sorted(v.line for v in hits(lint(tmp_path, "src"), "QSQ005"))
    assert lines == [4, 5]


def test_qsq005_dispatch_own_helpers_allowed(tmp_path):
    write(tmp_path, "src/repro/kernels/dispatch.py", """\
        import collections

        counters = collections.Counter()
        traffic = collections.Counter()

        def packed_matmul(p):
            counters[p.route] += 1

        def reset_counters():
            counters.clear()
            traffic.clear()
        """)
    assert not hits(lint(tmp_path, "src"), "QSQ005")


# --------------------------------------------------------------------------
# Pragmas + allowlist
# --------------------------------------------------------------------------
def test_pragma_trailing_suppresses_one_line(tmp_path):
    write(tmp_path, "src/repro/serve/hot.py", """\
        def forward(p, q, x):
            w = p.as_dense()  # qsqlint: disable=QSQ001 -- cold init
            v = q.as_dense()
            return (w + v) @ x
        """)
    vs = hits(lint(tmp_path, "src"), "QSQ001")
    assert [v.line for v in vs] == [3]


def test_pragma_standalone_comment_covers_next_code_line(tmp_path):
    write(tmp_path, "src/repro/serve/hot.py", """\
        def forward(p, x):
            # qsqlint: disable=QSQ001 -- multi-line justification
            # continues here; the pragma binds to the next code line
            w = p.as_dense()
            return w @ x
        """)
    assert not hits(lint(tmp_path, "src"), "QSQ001")


def test_pragma_disable_file_and_all(tmp_path):
    write(tmp_path, "src/repro/serve/whole.py", """\
        # qsqlint: disable-file=QSQ001 -- generated shim
        def forward(p, x):
            return p.as_dense() @ x
        """)
    write(tmp_path, "src/repro/serve/everything.py", """\
        def forward(p, x):
            return p.as_dense() @ x  # qsqlint: disable=all -- legacy
        """)
    assert not lint(tmp_path, "src")


def test_allowlist_suppresses_by_glob_and_qualname(tmp_path):
    write(tmp_path, "src/repro/serve/hot.py", """\
        def blessed(p):
            return p.as_dense()

        def cursed(p):
            return p.as_dense()
        """)
    cfg = Config(allow=("QSQ001:src/repro/serve/*.py:blessed",))
    vs = hits(lint(tmp_path, "src", config=cfg), "QSQ001")
    assert [v.qualname for v in vs] == ["cursed"]
    cfg_all = Config(allow=("QSQ001:src/repro/serve/*.py",))
    assert not lint(tmp_path, "src", config=cfg_all)


def test_syntax_error_reported_not_crash(tmp_path):
    write(tmp_path, "src/bad.py", "def broken(:\n")
    vs = lint(tmp_path, "src")
    assert [v.rule for v in vs] == ["QSQ000"]


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------
def test_cli_exit_codes(tmp_path, capsys):
    write(tmp_path, "src/repro/serve/hot.py", """\
        def forward(p, x):
            return p.as_dense() @ x
        """)
    assert qsqlint_main(["--root", str(tmp_path), "src"]) == 1
    out = capsys.readouterr().out
    assert "src/repro/serve/hot.py:2" in out and "QSQ001" in out

    write(tmp_path, "src/repro/serve/hot.py", """\
        def forward(p, x):
            return p.matmul(x)
        """)
    assert qsqlint_main(["--root", str(tmp_path), "src"]) == 0
    assert qsqlint_main(["--select", "QSQ999", "src"]) == 2
    assert qsqlint_main(["--list-rules"]) == 0
    assert "QSQ005" in capsys.readouterr().out


def test_cli_ignore_filters_rules(tmp_path):
    write(tmp_path, "src/repro/serve/hot.py", """\
        def forward(p, x):
            return p.as_dense() @ x
        """)
    assert qsqlint_main(
        ["--root", str(tmp_path), "--ignore", "QSQ001", "src"]) == 0


# --------------------------------------------------------------------------
# Self-lint: the repo must satisfy its own analyzer (the CI gate)
# --------------------------------------------------------------------------
def test_self_lint_repo_clean():
    vs = lint_paths(["src", "tests", "benchmarks"], root=REPO_ROOT)
    assert vs == [], "\n".join(v.format() for v in vs)


# --------------------------------------------------------------------------
# Runtime companion: no_retrace()
# --------------------------------------------------------------------------
def test_no_retrace_passes_on_cached_call(no_retrace):
    import jax
    import jax.numpy as jnp

    f = jax.jit(lambda x: x + 1)
    f(jnp.ones((2,)))
    with no_retrace(f):
        f(jnp.zeros((2,)))  # same shape: cache hit


def test_no_retrace_detects_new_trace(no_retrace):
    import jax
    import jax.numpy as jnp

    f = jax.jit(lambda x: x * 2)
    f(jnp.ones((2,)))
    with pytest.raises(AssertionError, match="retrace detected"):
        with no_retrace(f):
            f(jnp.ones((3,)))  # new shape: recompile


def test_no_retrace_detects_counter_drift(no_retrace):
    from repro.kernels import dispatch

    with pytest.raises(AssertionError, match="counters moved"):
        with no_retrace():
            # qsqlint: disable=QSQ005 -- seeds the drift this test detects
            dispatch.counters["drift"] += 1
    dispatch.reset_counters()


def test_no_retrace_rejects_unjitted(no_retrace):
    with pytest.raises(TypeError, match="_cache_size"):
        with no_retrace(lambda x: x):
            pass

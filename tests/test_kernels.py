"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codec
from repro.kernels import ops, ref

SHAPES = [
    # (M, K, N, G, bm, bk, bn)
    (32, 64, 32, 16, 32, 32, 32),
    (64, 128, 64, 16, 32, 64, 32),
    (128, 256, 128, 32, 64, 128, 64),
    (64, 512, 256, 64, 64, 256, 128),
    (8, 1024, 32, 128, 8, 512, 32),
]


@pytest.mark.parametrize("m,k,n,g,bm,bk,bn", SHAPES)
@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_qsq_matmul_vs_ref(m, k, n, g, bm, bk, bn, xdtype):
    key = jax.random.PRNGKey(m * 7 + k)
    w = jax.random.normal(key, (k, n)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(1), (m, k)).astype(xdtype)
    codes, scales = ref.qsq_quantize_ref(w, g, 4)
    planes = codec.pack_bitplane(codes)
    out_k = ops.qsq_matmul(x, planes, scales, group_size=g,
                           bm=bm, bk=bk, bn=bn, interpret=True)
    out_r = ref.qsq_matmul_ref(x, planes, scales, g)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("k,n,g", [(64, 32, 16), (256, 128, 32), (512, 64, 64)])
@pytest.mark.parametrize("phi", [1, 2, 4])
def test_qsq_quantize_vs_ref(k, n, g, phi):
    w = jax.random.normal(jax.random.PRNGKey(k + phi), (k, n)) * 0.1
    codes_k, scales_k = ops.qsq_quantize(w, group_size=g, phi=phi, interpret=True)
    codes_r, scales_r = ref.qsq_quantize_ref(w, g, phi)
    np.testing.assert_array_equal(np.asarray(codes_k), np.asarray(codes_r))
    np.testing.assert_allclose(np.asarray(scales_k), np.asarray(scales_r), rtol=1e-6)


def test_pack_weight_end_to_end():
    """pack_weight -> qsq_matmul equals dense matmul with dequantized w."""
    from repro.core.qsq import QSQConfig, dequantize, quantize

    k, n, g = 128, 64, 16
    w = jax.random.normal(jax.random.PRNGKey(3), (k, n)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(4), (8, k))
    planes, scales = ops.pack_weight(w, group_size=g, interpret=True)
    out = ops.qsq_matmul(x, planes, scales, group_size=g,
                         bm=8, bk=64, bn=32, interpret=True)
    wq = dequantize(quantize(w, QSQConfig(phi=4, group_size=g)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(x @ wq),
                               rtol=2e-5, atol=2e-4)


MATVEC_SHAPES = [
    # (M, K, N, G, bk, bn) — decode shapes: tiny M, deep K
    (1, 512, 64, 32, 256, 64),
    (8, 1024, 256, 64, 512, 128),
    (8, 2048, 512, 16, 1024, 256),
    (3, 256, 128, 128, 256, 128),
]


@pytest.mark.parametrize("m,k,n,g,bk,bn", MATVEC_SHAPES)
@pytest.mark.parametrize("xdtype", [jnp.float32, jnp.bfloat16])
def test_qsq_matvec_vs_ref(m, k, n, g, bk, bn, xdtype):
    key = jax.random.PRNGKey(m * 13 + k)
    w = jax.random.normal(key, (k, n)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(2), (m, k)).astype(xdtype)
    codes, scales = ref.qsq_quantize_ref(w, g, 4)
    planes = codec.pack_bitplane(codes)
    out_k = ops.qsq_matvec(x, planes, scales, group_size=g,
                           bk=bk, bn=bn, interpret=True)
    out_r = ref.qsq_matmul_ref(x, planes, scales, g)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-5, atol=2e-4)


def test_qsq_matvec_matches_qsq_matmul():
    """Both kernels decode the same planes to the same product."""
    m, k, n, g = 8, 1024, 256, 64
    w = jax.random.normal(jax.random.PRNGKey(9), (k, n)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(10), (m, k))
    codes, scales = ref.qsq_quantize_ref(w, g, 4)
    planes = codec.pack_bitplane(codes)
    a = ops.qsq_matvec(x, planes, scales, group_size=g, bk=512, bn=128,
                       interpret=True)
    b = ops.qsq_matmul(x, planes, scales, group_size=g, bm=8, bk=512, bn=128,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-6, atol=1e-6)


def test_matvec_rejects_bad_tiles():
    x = jnp.zeros((8, 96))
    planes = jnp.zeros((3, 3, 32), jnp.int32)
    scales = jnp.zeros((4, 32))  # group_size 24
    with pytest.raises(ValueError):  # bk=32 divides K but not group_size=24
        ops.qsq_matvec(x, planes, scales, group_size=24, bk=32, interpret=True)
    with pytest.raises(ValueError):  # tile does not divide N
        ops.qsq_matvec(x, planes, scales, group_size=24, bn=24, interpret=True)


def test_kernel_rejects_bad_tiles():
    x = jnp.zeros((32, 64))
    planes = jnp.zeros((2, 3, 32), jnp.int32)
    scales = jnp.zeros((4, 32))
    with pytest.raises(ValueError):  # scales shape inconsistent with group_size
        ops.qsq_matmul(x, planes, scales, group_size=32, interpret=True)
    with pytest.raises(ValueError):  # tile does not divide K
        ops.qsq_matmul(x, planes, scales, group_size=16, bk=48, interpret=True)


def test_xla_fallback_matches():
    k, n, g = 128, 64, 16
    w = jax.random.normal(jax.random.PRNGKey(5), (k, n)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(6), (16, k))
    codes, scales = ops.qsq_quantize(w, group_size=g, use_pallas=False)
    planes = codec.pack_bitplane(codes)
    a = ops.qsq_matmul(x, planes, scales, group_size=g, use_pallas=False)
    b = ops.qsq_matmul(x, planes, scales, group_size=g, bm=16, bk=64, bn=32,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-4)

"""Fault-tolerance tests: checkpoint/restart, preemption, stragglers,
grad compression, elastic restore."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.configs import get_arch
from repro.data.pipeline import LMDataConfig, lm_batch
from repro.models import Model
from repro.models.base import init_params
from repro.optim import AdamWConfig, GradCompressionConfig
from repro.train.trainer import Trainer, TrainerConfig


def _mk_trainer(tmp_path, steps=8, every=4, compression=False, name="ck"):
    cfg = get_arch("smollm_135m", smoke=True)
    model = Model(cfg)
    data = LMDataConfig(vocab=cfg.vocab, seq_len=16, global_batch=2)
    tc = TrainerConfig(
        total_steps=steps,
        log_every=1,
        opt=AdamWConfig(lr=1e-3),
        compression=GradCompressionConfig(enabled=compression, min_numel=64),
        checkpoint=CheckpointConfig(directory=str(tmp_path / name),
                                    every_steps=every, async_save=False),
    )
    return Trainer(model, tc, lambda s: lm_batch(data, s))


def _tree_equal(a, b):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    if len(fa) != len(fb):
        return False
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(fa, fb, strict=True))


def test_loss_decreases():
    cfg = get_arch("smollm_135m", smoke=True)
    model = Model(cfg)
    data = LMDataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    tc = TrainerConfig(total_steps=40, log_every=1, opt=AdamWConfig(lr=3e-3))
    tr = Trainer(model, tc, lambda s: lm_batch(data, s))
    tr.run()
    losses = [m["loss"] for m in tr.metrics_log]
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_checkpoint_resume_bit_exact(tmp_path):
    """Train 8 straight vs train 4 + preempt + resume 4: identical final
    state.  (Both legs use total_steps=8 so the LR schedule is identical.)"""
    t_full = _mk_trainer(tmp_path, steps=8, every=100, name="full")
    s_full, _ = t_full.run()

    t_a = _mk_trainer(tmp_path, steps=8, every=4, name="resume")

    def preempt(step, state, metrics):
        if step == 3:
            t_a.request_preemption()

    t_a.run(step_hook=preempt)  # stops + checkpoints at step 4
    t_b = _mk_trainer(tmp_path, steps=8, every=4, name="resume")
    state_b, last = t_b.run()  # resumes from 4, runs to 8
    assert last == 8
    assert _tree_equal(s_full.params, state_b.params)
    assert _tree_equal(s_full.opt.m, state_b.opt.m)


def test_preemption_checkpoints_and_resumes(tmp_path):
    tr = _mk_trainer(tmp_path, steps=100, every=1000, name="pre")
    hook_calls = []

    def hook(step, state, metrics):
        hook_calls.append(step)
        if step == 3:
            tr.request_preemption()

    state, last = tr.run(step_hook=hook)
    assert last == 4  # stopped right after step 3
    mgr = CheckpointManager(tr.cfg.checkpoint)
    assert mgr.latest_step() == 4
    # resume picks up where preemption left off
    tr2 = _mk_trainer(tmp_path, steps=6, every=1000, name="pre")
    state2, start = tr2.init_state()
    assert start == 4


def test_straggler_watchdog(tmp_path):
    tr = _mk_trainer(tmp_path, steps=20, every=1000, name="strag")

    def hook(step, state, metrics):
        if step == 15:
            time.sleep(1.0)  # inject a straggler step

    tr.run(step_hook=hook)
    assert any(e["step"] == 15 for e in tr.straggler_events)


def test_grad_compression_converges(tmp_path):
    """QSQ-compressed grads with error feedback still reduce the loss."""
    cfg = get_arch("smollm_135m", smoke=True)
    model = Model(cfg)
    data = LMDataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    tc = TrainerConfig(
        total_steps=40, log_every=1, opt=AdamWConfig(lr=3e-3),
        compression=GradCompressionConfig(enabled=True, min_numel=64),
    )
    tr = Trainer(model, tc, lambda s: lm_batch(data, s))
    tr.run()
    losses = [m["loss"] for m in tr.metrics_log]
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_grad_compression_wire_bytes_reported():
    from repro.train.state import train_state_descs
    from repro.train.step import make_train_step

    cfg = get_arch("smollm_135m", smoke=True)
    model = Model(cfg)
    cc = GradCompressionConfig(enabled=True, min_numel=64)
    step = make_train_step(model, AdamWConfig(), cc)
    state = init_params(jax.random.PRNGKey(0), train_state_descs(model, cc))
    tok = jnp.zeros((2, 16), jnp.int32)
    _, metrics = step(state, {"tokens": tok, "labels": tok})
    assert float(metrics["grad_wire_bytes"]) > 0


def test_elastic_restore_different_sharding(tmp_path):
    """Checkpoint saved unsharded restores under an explicit NamedSharding
    (mesh-shape change path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.checkpoint.manager import load_pytree, save_pytree

    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    path = tmp_path / "elastic.npz"
    save_pytree(tree, path)

    kw = ({"axis_types": (jax.sharding.AxisType.Auto,)}
          if hasattr(jax.sharding, "AxisType") else {})
    mesh = jax.make_mesh((1,), ("data",), **kw)
    shardings = {"w": NamedSharding(mesh, P("data", None))}
    restored = load_pytree(tree, path, sharding=shardings)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))
    assert restored["w"].sharding == shardings["w"]


def test_checkpoint_gc_keeps_last(tmp_path):
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path / "gc"),
                                             keep_last=2, async_save=False))
    tree = {"x": jnp.zeros((4,))}
    for s in (1, 2, 3, 4):
        mgr.save(tree, s, wait=True)
    assert mgr.all_steps() == [3, 4]


def test_wire_export(tmp_path):
    from repro.core.policy import QuantPolicy
    from repro.core.qsq import QSQConfig

    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path / "wire"),
                                             async_save=False))
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 32)) * 0.1}
    p = mgr.export_wire(params, QuantPolicy(base=QSQConfig(group_size=16),
                                            min_numel=256))
    assert p.exists()
    # wire artifact must be much smaller than f32
    assert p.stat().st_size < 64 * 32 * 4 * 0.5

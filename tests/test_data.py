"""Data pipeline: determinism + resumability + learnability structure."""
import numpy as np

from repro.data.pipeline import (
    LMDataConfig,
    image_batches,
    lm_batch,
    lm_batch_iterator,
    synthetic_image_dataset,
)


def test_lm_batch_deterministic():
    cfg = LMDataConfig(vocab=64, seq_len=16, global_batch=4)
    a = lm_batch(cfg, 7)
    b = lm_batch(cfg, 7)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))


def test_lm_labels_are_next_tokens():
    cfg = LMDataConfig(vocab=64, seq_len=16, global_batch=2)
    b = lm_batch(cfg, 0)
    np.testing.assert_array_equal(
        np.asarray(b["labels"][:, :-1]), np.asarray(b["tokens"][:, 1:])
    )


def test_iterator_resume_replays_stream():
    cfg = LMDataConfig(vocab=64, seq_len=8, global_batch=2)
    it = lm_batch_iterator(cfg)
    seen = [next(it) for _ in range(5)]
    state_after_3 = seen[2][0]
    it2 = lm_batch_iterator(cfg, state_after_3)
    s4, b4 = next(it2)
    np.testing.assert_array_equal(
        np.asarray(b4["tokens"]), np.asarray(seen[3][1]["tokens"])
    )


def test_lm_stream_has_structure():
    """Bigram stream: successors of each token come from <= branching set."""
    cfg = LMDataConfig(vocab=32, seq_len=256, global_batch=4, branching=4)
    b = lm_batch(cfg, 0)
    toks = np.asarray(b["tokens"])
    succ = {}
    for row in toks:
        for a, c in zip(row[:-1], row[1:], strict=True):
            succ.setdefault(int(a), set()).add(int(c))
    assert max(len(v) for v in succ.values()) <= 4


def test_image_dataset_separable():
    imgs, labels = synthetic_image_dataset(256, (28, 28), 1, 10, seed=0)
    assert imgs.shape == (256, 28, 28, 1)
    assert imgs.min() >= 0 and imgs.max() <= 1
    # same-class images are closer than cross-class on average
    d_same, d_diff = [], []
    for i in range(40):
        for j in range(i + 1, 40):
            d = float(((imgs[i] - imgs[j]) ** 2).mean())
            (d_same if labels[i] == labels[j] else d_diff).append(d)
    assert np.mean(d_same) < np.mean(d_diff)


def test_image_batches_resume():
    imgs, labels = synthetic_image_dataset(64, (8, 8), 1, 4)
    it1 = image_batches(imgs, labels, 8, seed=1, start_step=0)
    batches1 = [next(it1) for _ in range(4)]
    it2 = image_batches(imgs, labels, 8, seed=1, start_step=2)
    s, b = next(it2)
    assert s == 2
    np.testing.assert_array_equal(
        np.asarray(b["images"]), np.asarray(batches1[2][1]["images"])
    )

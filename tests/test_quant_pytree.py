"""Pytree quantization + wire (checkpoint/channel) format tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policy import QuantPolicy
from repro.core.qsq import QSQConfig, QSQTensor
from repro.models.base import init_params
from repro.quant import (
    dequantize_pytree,
    pack_pytree_wire,
    pytree_bits_report,
    quantize_pytree,
    unpack_pytree_wire,
)


def _params():
    return {
        "layer": {
            "w": jax.random.normal(jax.random.PRNGKey(0), (64, 32)) * 0.1,
            "bias": jnp.zeros((32,)),
            "norm_scale": jnp.ones((64,)),
        },
        "embed": jax.random.normal(jax.random.PRNGKey(1), (128, 64)) * 0.1,
    }


def test_policy_selects_matrices_only():
    params = _params()
    qp = quantize_pytree(params, QuantPolicy(base=QSQConfig(group_size=16), min_numel=512))
    assert isinstance(qp.tree["layer"]["w"], QSQTensor)
    assert isinstance(qp.tree["embed"], QSQTensor)
    assert not isinstance(qp.tree["layer"]["bias"], QSQTensor)  # 1-D
    assert not isinstance(qp.tree["layer"]["norm_scale"], QSQTensor)  # excluded


def test_dequantize_shapes_and_finiteness():
    params = _params()
    qp = quantize_pytree(params, QuantPolicy(base=QSQConfig(group_size=16), min_numel=512))
    deq = dequantize_pytree(qp)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(deq), strict=True):
        assert a.shape == b.shape
        assert np.isfinite(np.asarray(b)).all()


def test_wire_roundtrip_exact():
    """Wire (packed) -> unpack must reproduce codes and scales EXACTLY."""
    params = _params()
    qp = quantize_pytree(params, QuantPolicy(base=QSQConfig(group_size=16), min_numel=512))
    wire = pack_pytree_wire(qp)
    back = unpack_pytree_wire(wire)
    w1 = np.asarray(qp.tree["layer"]["w"].levels)
    w2 = np.asarray(back.tree["layer"]["w"].levels)
    np.testing.assert_array_equal(w1, w2)
    np.testing.assert_array_equal(
        np.asarray(qp.tree["layer"]["w"].scales),
        np.asarray(back.tree["layer"]["w"].scales),
    )
    # and dequantized views agree
    d1 = dequantize_pytree(qp)
    d2 = dequantize_pytree(back)
    np.testing.assert_allclose(
        np.asarray(d1["layer"]["w"]), np.asarray(d2["layer"]["w"])
    )


def test_bits_report_savings():
    params = _params()
    qp = quantize_pytree(params, QuantPolicy(base=QSQConfig(group_size=16), min_numel=512))
    rep = pytree_bits_report(params, qp)
    assert rep["n_quantized_leaves"] == 2
    assert 0.5 < rep["memory_savings"] < 0.95


def test_smoke_model_pytree_quantization():
    """Quantize a whole smoke model; loss must stay finite and in-family."""
    from repro.configs import get_arch
    from repro.models import Model

    cfg = get_arch("deepseek_7b", smoke=True)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_descs())
    qp = quantize_pytree(params, QuantPolicy(base=QSQConfig(group_size=16), min_numel=256))
    deq = dequantize_pytree(qp, like=params)
    tok = jnp.zeros((2, 16), jnp.int32)
    l0 = float(model.loss(params, {"tokens": tok, "labels": tok}))
    l1 = float(model.loss(deq, {"tokens": tok, "labels": tok}))
    assert np.isfinite(l1)
    assert abs(l1 - l0) < 2.0  # quantization is approximate, not destructive


def test_sensitivity_rank_and_budgeted_policy():
    """DESIGN.md §7.5: per-layer sensitivity ranking + phi-budget assignment."""
    import jax.numpy as jnp

    from repro.configs import get_arch
    from repro.core.policy import budgeted_policy, sensitivity_rank
    from repro.models import Model

    cfg = get_arch("deepseek_7b", smoke=True)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_descs())
    tok = jnp.zeros((2, 16), jnp.int32)
    batch = {"tokens": tok, "labels": tok}
    policy = QuantPolicy(base=QSQConfig(group_size=16), min_numel=256)

    sens = sensitivity_rank(params, lambda p, b: model.loss(p, b), policy, batch)
    assert len(sens) >= 3
    # ranked descending by loss increase
    deltas = [d for _, d in sens]
    assert deltas == sorted(deltas, reverse=True)

    bp = budgeted_policy(sens, policy)
    assert len(bp.overrides) == len(sens)
    # most sensitive layer gets the highest quality (phi=4)
    import re
    top_path = sens[0][0]
    assert bp.overrides[re.escape(top_path)].phi == 4

"""Self-speculative decoding: draft cheap, verify exact, roll back free.

The tentpole contract under test —

* a speculating request's tokens are IDENTICAL to plain decode at its
  serving tier — fuzzed over mixed speculating/non-speculating batches,
  mid-stream admissions and evictions, draft windows clamped by
  ``max_new``, and every acceptance boundary (full rejection, partial
  prefix, full window) — because the verify dispatch overwrites the
  draft-tier KV and the per-slot ``pos`` rollback masks rejected entries;
* the whole draft/verify round is retrace-free: drafting reuses the one
  continuous-decode program, the verify program traces once per
  (demand, window width) pair, and a warmed stream replays under
  ``no_retrace`` across all of it;
* the cost clock stays honest: draft ticks charge the draft demand
  floor's read fraction, a verify dispatch charges ONE serving-tier
  dispatch (never k), so SLO admission sees real weight reads;
* ``poll()`` surfaces per-request ``drafted``/``accepted`` counters, and
  guaranteed-useless speculation configs die at submit as typed
  ``SubmitRejected`` errors.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs.base import ArchConfig
from repro.kernels import dispatch
from repro.models.api import Model
from repro.models.base import init_params
from repro.quant.artifact import QualitySpec, QualityTier
from repro.serve import SpecConfig, SubmitRejected

SPEC_TIERS = QualitySpec((
    QualityTier("hi", drop_planes=0, drop_frac=0.0),
    QualityTier("mid", drop_planes=1, drop_frac=1.0),
    QualityTier("lo", drop_planes=2, drop_frac=1.0),
))

# a ladder whose "echo" tier drops NOTHING: drafting there is bit-identical
# to hi, so every draft is accepted — the deterministic full-window
# (a == k) boundary
ECHO_TIERS = QualitySpec((
    QualityTier("hi", drop_planes=0, drop_frac=0.0),
    QualityTier("echo", drop_planes=0, drop_frac=0.0),
))


def _build_artifact(tiers):
    cfg = ArchConfig(name="smollm-like", family="dense", n_layers=2,
                     d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256,
                     dtype=jnp.float32, remat=False)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_descs())
    return api.compress(model, params, tiers=tiers)


@pytest.fixture(scope="module")
def spec_artifact():
    return _build_artifact(SPEC_TIERS)


@pytest.fixture(scope="module")
def echo_artifact():
    return _build_artifact(ECHO_TIERS)


def _oracle(art, requests):
    """Plain solo decode of each request at its own tier — the token
    ground truth speculation must reproduce exactly."""
    engines = {}
    out = []
    for prompt, quality, max_new, _ in requests:
        if quality not in engines:
            engines[quality] = art.engine(quality=quality, batch_slots=1,
                                          max_prompt=8, max_len=32)
        out.append(engines[quality].generate([prompt], max_new=max_new)[0])
    return out


def _fuzz_requests(seed):
    """A deterministic mixed stream: speculating and plain requests at
    several tiers, draft windows larger than some budgets allow."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(6):
        prompt = rng.integers(1, 255, size=int(rng.integers(2, 7))).tolist()
        max_new = int(rng.integers(2, 8))
        roll = i % 3
        if roll == 0:
            quality, spec = "hi", SpecConfig("lo", k=int(rng.integers(1, 6)))
        elif roll == 1:
            quality, spec = "mid", SpecConfig("lo", k=int(rng.integers(1, 6)))
        else:
            quality, spec = rng.choice(["hi", "mid"]), None
        reqs.append((prompt, str(quality), max_new, spec))
    return reqs


def _run_stream(eng, requests):
    eng.reset_stream()
    rids = [eng.submit(p, max_new=m, quality=q, speculate=s)
            for p, q, m, s in requests]
    done = eng.run_until_drained()
    return [done[r].tokens for r in rids]


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_spec_token_identity_fuzz(spec_artifact, no_retrace, seed):
    """Speculative streams are token-identical to plain solo decode at
    each request's own tier, across mixed spec/plain batches with queueing
    (6 requests on 2 slots: mid-stream admits and evicts), and a warmed
    identical replay never retraces the decode/admit/verify programs."""
    art = spec_artifact
    requests = _fuzz_requests(seed)
    expect = _oracle(art, requests)
    eng = art.engine(quality="hi", batch_slots=2, max_prompt=8, max_len=32)
    assert _run_stream(eng, requests) == expect  # warm every trace
    with no_retrace(eng._cont_step, eng._admit, eng._verify):
        assert _run_stream(eng, requests) == expect
    stats = eng.stream_stats()
    assert stats["drafted"] > 0
    assert 0 <= stats["accepted"] <= stats["drafted"]


def test_spec_full_window_acceptance(echo_artifact):
    """Drafting at a tier that drops nothing is bit-identical to hi, so
    every round accepts its whole window (the a == k rollback boundary)
    and the acceptance rate is exactly 1.0."""
    art = echo_artifact
    requests = [([7, 7, 7], "hi", 9, SpecConfig("echo", k=3)),
                ([5, 2], "hi", 7, SpecConfig("echo", k=2))]
    expect = _oracle(art, requests)
    eng = art.engine(quality="hi", batch_slots=2, max_prompt=8, max_len=32)
    assert _run_stream(eng, requests) == expect
    stats = eng.stream_stats()
    assert stats["drafted"] > 0
    assert stats["acceptance_rate"] == 1.0


def test_spec_k_clamped_by_remaining_budget(spec_artifact):
    """k larger than the remaining max_new budget clamps the draft window
    (never drafts past the last token); max_new == 2 leaves no room to
    draft at all and serves as plain decode."""
    art = spec_artifact
    requests = [([3, 1, 4], "hi", 2, SpecConfig("lo", k=5)),
                ([1, 5, 9], "hi", 4, SpecConfig("lo", k=5))]
    expect = _oracle(art, requests)
    eng = art.engine(quality="hi", batch_slots=2, max_prompt=8, max_len=32)
    rids = [eng.submit(p, max_new=m, quality=q, speculate=s)
            for p, q, m, s in requests]
    done = eng.run_until_drained()
    assert [done[r].tokens for r in rids] == expect
    assert done[rids[0]].drafted == 0          # no room: 1 + k > max_new
    assert 0 < done[rids[1]].drafted <= 3      # clamped below k=5
    assert len(done[rids[0]].tokens) == 2
    assert len(done[rids[1]].tokens) == 4


def test_spec_mid_stream_cancel_keeps_survivors_exact(spec_artifact):
    """Cancelling a speculating request mid-stream (active-mask flip) does
    not perturb the batch mates' tokens."""
    art = spec_artifact
    keep = ([2, 4, 6], "hi", 6, SpecConfig("lo", k=2))
    expect = _oracle(art, [keep])[0]
    eng = art.engine(quality="hi", batch_slots=2, max_prompt=8, max_len=32)
    r_keep = eng.submit(keep[0], max_new=keep[2], quality=keep[1],
                        speculate=keep[3])
    r_dead = eng.submit([9, 9], max_new=6, quality="hi",
                        speculate=SpecConfig("mid", k=3))
    eng.step()  # both admitted and one round in flight
    st = eng.cancel(r_dead)
    assert st.finish_reason is not None
    done = eng.run_until_drained()
    assert done[r_keep].tokens == expect


def test_spec_status_counters_surface_via_poll(spec_artifact):
    art = spec_artifact
    eng = art.engine(quality="hi", batch_slots=1, max_prompt=8, max_len=32)
    rid = eng.submit([1, 2, 3], max_new=6, speculate=SpecConfig("lo", k=2))
    eng.step()
    live = eng.poll(rid)  # mid-flight reads see live draft counters
    assert live.drafted >= 0 and live.accepted <= live.drafted
    done = eng.run_until_drained()[rid]
    assert len(done.tokens) == 6
    assert done.drafted > 0
    assert 0 <= done.accepted <= done.drafted


def test_spec_cost_clock_charges_verify_as_one_tick(spec_artifact):
    """Satellite-6 honesty: one admission step with a lone speculating
    slot costs exactly prefill(hi) + k_eff x draft(lo) + ONE verify(hi)
    on the cost clock — a verify dispatch is never charged k."""
    art = spec_artifact
    eng = art.engine(quality="hi", batch_slots=1, max_prompt=8, max_len=32)
    costs = eng.tier_cost_table()  # per-tier dispatch read fractions
    rid = eng.submit([1, 2, 3], max_new=8, speculate=SpecConfig("lo", k=3))
    info = eng.step()
    assert info.drafted == 3
    lo = eng.tier_names.index("lo")
    expect = costs[0] + 3 * costs[lo] + costs[0]
    assert info.cost == pytest.approx(expect, rel=1e-9)
    assert costs[lo] < costs[0]  # the draft tier is genuinely cheaper
    eng.run_until_drained()
    assert eng.poll(rid).n_tokens == 8


def test_spec_phase_labeled_traffic(spec_artifact):
    """A freshly traced speculative stream attributes plane words to the
    draft and verify phases in dispatch.traffic (trace-time accounting,
    like every dispatch counter)."""
    art = spec_artifact
    dispatch.reset_counters()
    eng = art.engine(quality="hi", batch_slots=1, max_prompt=8, max_len=32)
    eng.submit([1, 2, 3], max_new=6, speculate=SpecConfig("lo", k=2))
    eng.run_until_drained()
    assert dispatch.traffic["phase:draft:plane_words_read"] > 0
    assert dispatch.traffic["phase:verify:plane_words_read"] > 0
    # the draft program streams fewer words than its full-plane footprint
    assert (dispatch.traffic["phase:draft:plane_words_read"]
            < dispatch.traffic["phase:draft:plane_words_full"])


def test_spec_submit_validation(spec_artifact):
    art = spec_artifact
    eng = art.engine(quality="hi", batch_slots=1, max_prompt=8, max_len=32)
    with pytest.raises(SubmitRejected):
        eng.submit([1], speculate=SpecConfig("lo", k=0))
    with pytest.raises(SubmitRejected):
        eng.submit([1], speculate=SpecConfig("nope", k=2))
    with pytest.raises(SubmitRejected):  # draft not BELOW the serving tier
        eng.submit([1], quality="lo", speculate=SpecConfig("lo", k=2))
    with pytest.raises(SubmitRejected):
        eng.submit([1], quality="mid", speculate=SpecConfig("mid", k=2))
    single = art.engine(quality="hi", per_request=False, batch_slots=1,
                        max_prompt=8, max_len=32)
    with pytest.raises(SubmitRejected):
        single.submit([1], speculate=SpecConfig("lo", k=2))

"""The quality-dial API: EdgeArtifact facade + plane-truncated serving."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs.base import ArchConfig
from repro.models.api import Model
from repro.models.base import init_params
from repro.quant import tree_bits_report
from repro.quant.store import PackedWeight, QSQWeight, max_level_delta
from repro.serve import ServeConfig, ServeEngine

PROMPTS = [[1, 2, 3], [9, 9]]


def _model_and_params():
    cfg = ArchConfig(name="smollm-like", family="dense", n_layers=2,
                     d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256,
                     dtype=jnp.float32, remat=False)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_descs())
    return model, params


@pytest.fixture(scope="module")
def artifact():
    model, params = _model_and_params()
    return api.compress(model, params), model, params


# -- plane-truncated PackedWeight ----------------------------------------
def _a_packed_leaf(art) -> PackedWeight:
    params, _ = art.serve_params(quality="hi")
    leaf = params["embed"]["head"]
    assert isinstance(leaf, PackedWeight)
    return leaf


def test_truncate_nbits_monotone(artifact):
    art, _, _ = artifact
    pw = _a_packed_leaf(art)
    bits = [pw.truncate(d).nbits() for d in (0, 1, 2)]
    assert bits[0] > bits[1] > bits[2]
    # idempotent and counted from full quality
    assert pw.truncate(1).truncate(1).nbits() == bits[1]
    assert pw.truncate(1).n_planes == 2


def test_truncate_error_bound(artifact):
    """as_dense() of a truncated view stays within max_level_delta * alpha."""
    art, _, _ = artifact
    pw = _a_packed_leaf(art)
    full = np.asarray(pw.as_dense())
    scales = np.asarray(pw.scales)  # (K//G, N)
    g = pw.group_size
    for drop in (1, 2):
        err = np.abs(np.asarray(pw.truncate(drop).as_dense()) - full)
        err_g = err.reshape(scales.shape[0], g, -1)
        bound = max_level_delta(drop) * scales[:, None, :] + 1e-6
        assert np.all(err_g <= bound)


def test_truncate_matmul_matches_dense(artifact):
    """Kernel-path matmul on the truncated view == x @ truncated dense."""
    art, _, _ = artifact
    pw = _a_packed_leaf(art).truncate(1)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, pw.shape[0]), jnp.float32)
    got = np.asarray(pw.matmul(x))
    want = np.asarray(x) @ np.asarray(pw.as_dense())
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_qsq_truncate_matches_packed_truncate(artifact):
    """Level-space truncation == plane-space truncation, bit for bit."""
    art, _, _ = artifact
    store = art.tree()
    leaf = store["embed"]["head"]
    assert isinstance(leaf, QSQWeight)
    via_levels = np.asarray(leaf.truncate(1).as_dense())
    via_planes = np.asarray(leaf.pack().truncate(1).as_dense())
    np.testing.assert_array_equal(via_levels, via_planes)


# -- the quality dial ----------------------------------------------------
def test_tier_bits_strictly_decreasing(artifact):
    art, _, _ = artifact
    bits = []
    for q in art.quality_names():
        params, n_packed = art.serve_params(quality=q)
        assert n_packed > 0  # every tier serves packed — no re-quantize path
        bits.append(tree_bits_report(params)["bits"])
    assert bits[0] > bits[1] > bits[2]


def test_engine_quality_tiers_generate(artifact):
    art, _, _ = artifact
    for q in art.quality_names():
        eng = art.engine(quality=q, batch_slots=4)
        outs = eng.generate(PROMPTS, max_new=6)
        assert len(outs) == 2 and all(len(o) == 6 for o in outs)


def test_set_quality_matches_fresh_engine(artifact):
    art, _, _ = artifact
    eng = art.engine(quality="hi", batch_slots=4)
    eng.set_quality("lo")
    assert eng.quality == "lo"
    fresh = art.engine(quality="lo", batch_slots=4)
    assert (eng.generate(PROMPTS, max_new=6)
            == fresh.generate(PROMPTS, max_new=6))
    assert (tree_bits_report(eng.params)["bits"]
            == tree_bits_report(fresh.params)["bits"])


def test_set_quality_requires_artifact():
    model, params = _model_and_params()
    eng = ServeEngine(model, params, ServeConfig(batch_slots=2))
    with pytest.raises(ValueError, match="EdgeArtifact"):
        eng.set_quality("lo")


# -- save / load ---------------------------------------------------------
def test_save_load_engine_tokens_identical(artifact, tmp_path):
    art, _, _ = artifact
    path = art.save(tmp_path / "m.edge.npz")
    art2 = api.load(path)
    assert art2.arch == art.arch
    assert art2.quality_names() == art.quality_names()
    assert art2.drop_map("mid") == art.drop_map("mid")
    for q in art.quality_names():
        a = art.engine(quality=q, batch_slots=4).generate(PROMPTS, max_new=8)
        b = art2.engine(quality=q, batch_slots=4).generate(PROMPTS, max_new=8)
        assert a == b


def test_saved_artifact_lower_tier_fewer_bits(artifact, tmp_path):
    """Acceptance: one saved artifact serves a lower tier with strictly
    fewer nbits, without re-quantizing.  per_request=False pins the
    single-tier layout (physically truncated planes — what an edge
    receiver of the truncated wire stores); the per-request default keeps
    full planes so one tree can serve every tier per slot."""
    art, _, _ = artifact
    art2 = api.load(art.save(tmp_path / "m.edge.npz"))
    hi = art2.engine(quality="hi", batch_slots=2, per_request=False)
    lo = art2.engine(quality="lo", batch_slots=2, per_request=False)
    assert (tree_bits_report(lo.params)["bits"]
            < tree_bits_report(hi.params)["bits"])
    assert lo.n_packed_leaves == hi.n_packed_leaves > 0
    assert len(lo.generate([[1, 2]], max_new=4)[0]) == 4
    # the per-request default serves the same lo tokens from full planes
    pr = art2.engine(quality="lo", batch_slots=2)
    assert pr.per_request_quality
    assert (pr.generate([[1, 2]], max_new=4)
            == lo.generate([[1, 2]], max_new=4))


def test_legacy_from_wire_matches_artifact_hi(artifact):
    """Acceptance: the deprecated ServeEngine.from_wire path and
    EdgeArtifact.engine(quality='hi') emit identical greedy tokens."""
    art, model, _ = artifact
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = ServeEngine.from_wire(model, art.wire,
                                       ServeConfig(batch_slots=4))
    hi = art.engine(quality="hi", batch_slots=4)
    assert (legacy.generate(PROMPTS, max_new=8)
            == hi.generate(PROMPTS, max_new=8))
    assert legacy.n_packed_leaves == hi.n_packed_leaves


def test_from_wire_warns_deprecated(artifact):
    art, model, _ = artifact
    with pytest.warns(DeprecationWarning, match="repro.api.compress"):
        ServeEngine.from_wire(model, art.wire, ServeConfig(batch_slots=2))


def test_checkpoint_wire_loads_as_artifact(artifact, tmp_path):
    """export_wire output (no meta) loads as a bare artifact; its wire tree
    serves identically through an explicitly-provided arch config."""
    from repro.checkpoint.manager import CheckpointConfig, CheckpointManager
    from repro.core.policy import QuantPolicy
    from repro.core.qsq import QSQConfig
    from repro.quant.artifact import EdgeArtifact

    art, model, params = artifact
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path / "w"),
                                             async_save=False))
    mgr.export_wire(params, QuantPolicy(base=QSQConfig(group_size=16,
                                                       refit_alpha=True),
                                        min_numel=512),
                    descs=model.param_descs())
    bare = api.load(mgr.dir / "wire.npz")
    assert bare.arch_config is None and bare.rank == ()
    with pytest.raises(ValueError, match="arch config"):
        bare.model()
    eng = EdgeArtifact(wire=bare.wire, arch_config=model.cfg).engine(
        quality="hi", batch_slots=2)
    assert eng.n_packed_leaves > 0
    # a rank-less artifact must refuse lower tiers rather than silently
    # serving full quality under a lower tier's name
    with pytest.raises(ValueError, match="sensitivity ranking"):
        eng.set_quality("lo")


def test_engine_rejects_cfg_and_kwargs(artifact):
    from repro.serve import ServeConfig

    art, _, _ = artifact
    with pytest.raises(TypeError, match="not both"):
        art.engine(quality="hi", serve_cfg=ServeConfig(batch_slots=2),
                   batch_slots=4)


# -- model-free (CNN) path ----------------------------------------------
def test_model_free_compress_dense_tiers():
    rng = np.random.RandomState(0)
    params = {"w1": jnp.asarray(rng.randn(64, 32), jnp.float32),
              "w2": jnp.asarray(rng.randn(64, 48), jnp.float32)}
    art = api.compress(None, params)
    assert len(art.rank) == 2
    hi = art.dense_params(quality="hi", like=params)
    lo = art.dense_params(quality="lo", like=params)
    assert hi["w1"].shape == params["w1"].shape
    # lo really truncates: reconstruction differs from hi somewhere
    assert any(
        not np.array_equal(np.asarray(hi[k]), np.asarray(lo[k]))
        for k in params
    )
    with pytest.raises(ValueError, match="arch config"):
        art.engine()


# -- generate() fixes ----------------------------------------------------
def test_generate_empty_prompt_list(artifact):
    art, _, _ = artifact
    assert art.engine(quality="hi", batch_slots=2).generate([]) == []


def test_generate_empty_prompt_raises(artifact):
    art, _, _ = artifact
    eng = art.engine(quality="hi", batch_slots=2)
    with pytest.raises(ValueError, match="at least one token"):
        eng.generate([[1, 2], []])


def test_generate_too_many_prompts_message(artifact):
    art, _, _ = artifact
    eng = art.engine(quality="hi", batch_slots=2)
    with pytest.raises(ValueError, match="batch_slots"):
        eng.generate([[1], [2], [3]])


def test_generate_temperature_sampling(artifact):
    art, _, _ = artifact
    eng = art.engine(quality="hi", batch_slots=2, temperature=0.8)
    a = eng.generate([[1, 2, 3]], max_new=8, seed=7)
    b = eng.generate([[1, 2, 3]], max_new=8, seed=7)
    c = eng.generate([[1, 2, 3]], max_new=8, seed=8)
    assert a == b  # same seed reproduces
    assert all(0 <= t < 256 for t in a[0])
    # a different seed (or greedy) is allowed to differ; just sanity-check
    # the sampled path actually ran the sampler
    assert eng._sample_loop is not None
    greedy = art.engine(quality="hi", batch_slots=2)
    assert greedy._sample_loop is None
    assert len(c[0]) == 8

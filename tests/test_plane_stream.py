"""Demand-driven plane streaming: layout, routing, wire v2 and traffic.

The tentpole contract under test —

* the plane-major layout is a lossless, invertible re-view of the packed
  planes, and plane truncation on it zeroes a TRAILING prefix-complement,
  so the demand-routed kernel can shorten the HBM read instead of masking
  post-load;
* ``matmul(x, plane_mask, demand_tier=t)`` is bit-identical to the PR 5
  masked path (``demand_tier=None``) for every tier mix whose live rows
  all sit at tier >= t, across the GEMV / GEMM / XLA dispatch routes;
* sign-magnitude (wire v2) codes make plane truncation sign-symmetric,
  and the wire codec round-trips v2 while still reading legacy Table II
  dicts;
* the dispatch ``traffic`` counter reports planes-touched x tiles and
  plane words read/full per routed call;
* the continuous engine computes per-tick demand from live slots only,
  never retraces beyond one trace per tier, and its analytic stream
  meter shows an all-lo batch reading <= 0.5x the all-hi weight bytes.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs.base import ArchConfig
from repro.core.qsq import QSQConfig, quantize
from repro.kernels import dispatch
from repro.models.api import Model
from repro.models.base import init_params
from repro.quant.artifact import QualitySpec, QualityTier
from repro.quant.store import (
    QSQWeight,
    plane_mask_for_drop,
    set_packed_matmul_kernel,
    wire_decode_leaf,
    wire_encode_leaf,
)
from repro.serve.scheduler import plane_demand


def _packed(k, n, g, seed, tier_drops=None, plane_major=False):
    rng = np.random.RandomState(seed)
    w = jnp.asarray(rng.randn(k, n), jnp.float32)
    q = QSQWeight.from_tensor(
        quantize(w, QSQConfig(group_size=g, refit_alpha=True)), rest_ndim=1
    )
    pw = q.pack()
    if tier_drops is not None:
        pw = dataclasses.replace(pw, tier_drops=tuple(tier_drops))
    return pw.to_plane_major() if plane_major else pw


# --------------------------------------------------------------------------
# Layout: plane-major <-> interleaved
# --------------------------------------------------------------------------
def test_plane_major_roundtrip_lossless():
    pw = _packed(64, 48, 16, 0)
    pm = pw.to_plane_major()
    assert pm.plane_major and pm.to_plane_major() is pm  # idempotent
    back = pm.to_interleaved()
    np.testing.assert_array_equal(np.asarray(back.planes),
                                  np.asarray(pw.planes))
    np.testing.assert_array_equal(np.asarray(pm.as_dense()),
                                  np.asarray(pw.as_dense()))
    assert pm.shape == pw.shape and pm.nbits() == pw.nbits()


def test_plane_major_truncate_zeroes_trailing_planes():
    """LSB truncation on the MSB-first plane-major layout zeroes TRAILING
    plane slots — the kept planes are a leading prefix, which is what lets
    the kernel's BlockSpec stop reading early."""
    pw = _packed(96, 40, 32, 1)
    for drop in (1, 2):
        tr_pm = pw.to_plane_major().truncate(drop)
        np.testing.assert_array_equal(
            np.asarray(tr_pm.planes[3 - drop:]), 0)
        assert np.asarray(tr_pm.planes[:3 - drop]).any()
        # same dense view as truncating the interleaved layout
        np.testing.assert_array_equal(
            np.asarray(tr_pm.as_dense()),
            np.asarray(pw.truncate(drop).as_dense()))
        assert tr_pm.demand_drop() == drop  # physical floor, no tiers


def test_stacked_plane_major_keeps_layer_axis_leading():
    """The plane axis sits AFTER the stack axes, so layer-scan slicing of
    axis 0 still yields per-layer leaves on plane-major trees."""
    pw = _packed(64, 16, 16, 2)
    stacked = dataclasses.replace(
        pw, planes=jnp.stack([pw.planes, pw.planes]),
        scales=jnp.stack([pw.scales, pw.scales]))
    pm = stacked.to_plane_major()
    assert pm.planes.shape == (2, 3) + pw.planes.shape[0:1] + pw.planes.shape[2:]
    assert pm.shape == (2,) + pw.shape


# --------------------------------------------------------------------------
# Demand routing == the PR 5 masked path, every tier mix, every route
# --------------------------------------------------------------------------
@pytest.mark.parametrize("m,route", [(4, "gemv"), (64, "gemm"), (4, "xla")])
def test_demand_routed_bit_identical_to_masked(m, route):
    tier_drops = (0, 1, 2)
    pw = _packed(64, 48, 16, 3, tier_drops=tier_drops, plane_major=True)
    rng = np.random.RandomState(4)
    x = jnp.asarray(rng.randn(m, 64), jnp.float32)
    masks_tbl = pw.tier_plane_masks()
    set_packed_matmul_kernel(route != "xla")
    try:
        for demand in (0, 1, 2):
            # every mix of live tiers at or above the demand floor
            tiers = jnp.asarray(rng.randint(demand, 3, size=m), jnp.int32)
            baseline = np.asarray(pw.matmul(x, plane_mask=masks_tbl[tiers]))
            routed = np.asarray(pw.matmul(x, plane_mask=masks_tbl[tiers],
                                          demand_tier=demand))
            np.testing.assert_array_equal(routed, baseline, err_msg=(
                f"route={route} demand={demand}"))
    finally:
        set_packed_matmul_kernel(True)


def test_demand_prunes_stale_rows_to_zero():
    """A row whose mask demands a PRUNED variant (stale dead-lane tier
    below the floor) reads exact zeros — the engine discards dead-lane
    outputs, so zeros are safe, but they must be deterministic."""
    pw = _packed(64, 32, 16, 5, tier_drops=(0, 1, 2), plane_major=True)
    x = jnp.ones((4, 64), jnp.float32)
    masks = jnp.asarray([plane_mask_for_drop(0), plane_mask_for_drop(1),
                         plane_mask_for_drop(2), plane_mask_for_drop(1)],
                        jnp.int32)
    out = np.asarray(pw.matmul(x, plane_mask=masks, demand_tier=1))
    np.testing.assert_array_equal(out[0], 0)      # drop-0 row: pruned
    assert np.abs(out[1:]).sum() > 0              # demanded rows survive
    want = np.asarray(pw.matmul(x, plane_mask=masks))
    np.testing.assert_array_equal(out[1:], want[1:])


def test_demand_drop_suffix_min_handles_nonmonotone_tiers():
    pw = _packed(32, 8, 16, 6, tier_drops=(1, 2, 0, 2))
    # interleaved: demand never shortens (no physical prefix to skip)
    assert [pw.demand_drop(t) for t in (None, 0, 1, 2, 3)] == [0, 0, 0, 0, 2]
    pm = pw.to_plane_major()
    assert [pm.demand_drop(t) for t in (0, 1, 2, 3)] == [0, 0, 0, 2]
    assert pm.truncate(1).demand_drop(0) == 1  # physical floor widens


def test_unmasked_demand_requires_plane_major():
    from repro.kernels import ops

    pw = _packed(64, 32, 16, 7)
    x = jnp.ones((2, 64), jnp.float32)
    with pytest.raises(ValueError, match="plane-major"):
        ops.qsq_matvec(x, pw.planes.reshape(2, 3, 32), pw.scales,
                       group_size=16, demand_drop=1)


# --------------------------------------------------------------------------
# Sign-magnitude codes (wire v2)
# --------------------------------------------------------------------------
def test_sign_symmetric_truncation():
    """Wire v2's reason to exist: +v and -v degrade IDENTICALLY under
    plane truncation (Table II offset codes truncated +1 to 0 but -1 to
    -2, biasing truncated tiers negative)."""
    levels = jnp.asarray([[0, 1, 2, 4, -1, -2, -4, 1]], jnp.float32).T
    q = QSQWeight(levels=levels, scales=jnp.ones((1, 1)), group_size=8,
                  phi=4, rest_ndim=1)
    for drop in (1, 2):
        t = np.asarray(q.truncate(drop).levels)[:, 0]
        pos, neg = t[1:4], t[4:7]
        np.testing.assert_array_equal(pos, -neg)


def test_wire_v2_roundtrip_and_legacy_shim():
    from repro.core import codec
    from repro.core.qsq import levels_to_codes

    pw_src = _packed(64, 24, 16, 8)
    q = pw_src.unpack()
    d = wire_encode_leaf(q)
    assert int(np.asarray(d["code_fmt"])) == 2
    back = wire_decode_leaf(d)
    np.testing.assert_array_equal(np.asarray(back.levels),
                                  np.asarray(q.levels))
    # legacy v1 dict: Table II offset codes, no code_fmt key
    legacy = dict(d)
    del legacy["code_fmt"]
    legacy["packed"] = codec.pack_dense(
        levels_to_codes(jnp.asarray(q.levels)).reshape(-1), bits=3)
    old = wire_decode_leaf(legacy)
    np.testing.assert_array_equal(np.asarray(old.levels),
                                  np.asarray(q.levels))
    bad = dict(d, code_fmt=9)
    with pytest.raises(ValueError, match="code_fmt"):
        wire_decode_leaf(bad)


def test_pack_defaults_to_sign_magnitude():
    pw = _packed(64, 16, 16, 9)
    assert pw.sign_mag
    legacy = _packed(64, 16, 16, 9).unpack().pack(sign_mag=False)
    assert not legacy.sign_mag
    np.testing.assert_array_equal(np.asarray(pw.as_dense()),
                                  np.asarray(legacy.as_dense()))


# --------------------------------------------------------------------------
# Traffic accounting
# --------------------------------------------------------------------------
def test_traffic_counts_demand_shortened_reads():
    pw = _packed(64, 48, 16, 10, tier_drops=(0, 1, 2), plane_major=True)
    x = jnp.ones((4, 64), jnp.float32)
    masks = pw.tier_plane_masks()
    dispatch.reset_counters()
    pw.matmul(x, plane_mask=masks[jnp.zeros(4, jnp.int32)], demand_tier=0)
    full = dispatch.traffic["plane_words_read"]
    assert full == dispatch.traffic["plane_words_full"] > 0
    route = dispatch.plan(4, 64, 48, 16).route
    assert dispatch.traffic[f"{route}:planes3"] == 1
    dispatch.reset_counters()
    pw.matmul(x, plane_mask=masks[jnp.full(4, 2, jnp.int32)], demand_tier=2)
    assert dispatch.traffic["plane_words_read"] * 3 == full
    assert dispatch.traffic[f"{route}:planes1"] == 1
    assert dispatch.traffic["plane_reads"] > 0
    dispatch.reset_counters()
    # interleaved leaves can't shorten: always 3 planes streamed
    pw.to_interleaved().matmul(x, plane_mask=masks[jnp.full(4, 2, jnp.int32)],
                               demand_tier=2)
    assert (dispatch.traffic["plane_words_read"]
            == dispatch.traffic["plane_words_full"])
    dispatch.reset_counters()


def test_reset_counters_clears_traffic():
    dispatch.traffic["x"] = 1  # qsqlint: disable=QSQ005 -- seeds the reset test
    dispatch.counters["y"] = 1  # qsqlint: disable=QSQ005 -- seeds the reset test
    dispatch.reset_counters()
    assert not dispatch.traffic and not dispatch.counters


# --------------------------------------------------------------------------
# Scheduler demand + engine integration
# --------------------------------------------------------------------------
def test_plane_demand_is_min_live_tier():
    assert plane_demand([2, 0, 1]) == 0
    assert plane_demand([2, 2]) == 2
    assert plane_demand([], default=1) == 1
    assert plane_demand(iter(np.asarray([1, 2], np.int32))) == 1


STREAM_TIERS = QualitySpec((
    QualityTier("hi", drop_planes=0, drop_frac=0.0),
    QualityTier("mid", drop_planes=1, drop_frac=1.0),
    QualityTier("lo", drop_planes=2, drop_frac=1.0),
))


@pytest.fixture(scope="module")
def stream_artifact():
    cfg = ArchConfig(name="smollm-like", family="dense", n_layers=2,
                     d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256,
                     dtype=jnp.float32, remat=False)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_descs())
    return api.compress(model, params, tiers=STREAM_TIERS)


def test_engine_demand_updates_without_retrace(stream_artifact, no_retrace):
    """Admissions and evictions move the per-tick demand; after one warm
    trace per tier neither program retraces again, whatever the mix."""
    art = stream_artifact
    eng = art.engine(quality="hi", batch_slots=2, max_prompt=6, max_len=16)
    for q in art.quality_names():  # warm one trace per demand pattern
        eng.submit([3, 1], max_new=2, quality=q)
        eng.run_until_drained()
    n_tiers = len(art.quality_names())
    assert eng._cont_step._cache_size() == n_tiers
    assert eng._admit._cache_size() == n_tiers
    # lo decoding alone (demand=lo), hi admitted mid-stream (demand drops
    # to hi), hi evicts first (demand returns to lo): three demand moves
    with no_retrace(eng._cont_step, eng._admit):
        r_lo = eng.submit([9, 9], max_new=8, quality="lo")
        eng.step()
        r_hi = eng.submit([5, 5], max_new=2, quality="hi")
        out = eng.run_until_drained()
    assert len(out[r_lo].tokens) == 8 and len(out[r_hi].tokens) == 2


def test_engine_stream_meter_all_lo_under_half_of_all_hi(stream_artifact):
    """ISSUE acceptance: all-lo bytes-read-per-token <= 0.5x all-hi
    (analytic meter; the tier ladder keeps one plane at lo, so the exact
    ratio is 1/3)."""
    art = stream_artifact
    eng = art.engine(quality="hi", batch_slots=2, max_prompt=6, max_len=16)
    prompts = [[1, 2], [7, 7, 7], [4], [9, 9]]

    def run_mix(quality):
        eng.reset_stream()
        for p in prompts:
            eng.submit(p, max_new=4, quality=quality)
        eng.run_until_drained()
        return eng.stream_stats()

    hi, lo = run_mix("hi"), run_mix("lo")
    assert hi["tokens"] == lo["tokens"] == len(prompts) * 4
    assert hi["read_frac"] == 1.0
    assert lo["bytes_per_token"] <= 0.5 * hi["bytes_per_token"]
    assert lo["read_frac"] == pytest.approx(1 / 3, abs=1e-6)

"""End-to-end behaviour tests: the paper's full methodology on a real
(small) model + the framework loop (train -> quantize -> transfer -> serve).
"""
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.policy import QuantPolicy
from repro.core.qsq import QSQConfig
from repro.data.pipeline import LMDataConfig, image_batches, lm_batch, synthetic_image_dataset
from repro.models import Model
from repro.models.base import init_params
from repro.models.cnn import LENET, cnn_accuracy, cnn_descs, cnn_loss
from repro.optim import AdamWConfig, adamw_init_descs, adamw_update
from repro.quant import dequantize_pytree, pack_pytree_wire, quantize_pytree
from repro.serve import ServeConfig, ServeEngine


def _train_lenet(steps=300, lr=2e-3, n=1024):
    imgs, labels = synthetic_image_dataset(n, LENET.input_hw, LENET.input_c,
                                           LENET.n_classes, seed=0)
    params = init_params(jax.random.PRNGKey(0), cnn_descs(LENET))
    opt = init_params(jax.random.PRNGKey(0), adamw_init_descs(cnn_descs(LENET)))
    ocfg = AdamWConfig(lr=lr, weight_decay=0.0)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: cnn_loss(p, LENET, batch)
        )(params)
        params, opt, _ = adamw_update(ocfg, params, grads, opt)
        return params, opt, loss

    it = image_batches(imgs, labels, 64, seed=1)
    for _ in range(steps):
        _, batch = next(it)
        params, opt, loss = step(params, opt, batch)
    return params, imgs, labels


def test_lenet_paper_pipeline():
    """Table III methodology: train -> quantize -> accuracy stays close;
    plus the +zeros and model-size claims."""
    from repro.core.qsq import zeros_fraction

    params, imgs, labels = _train_lenet()
    acc_fp = cnn_accuracy(params, LENET, imgs[:256], labels[:256])
    assert acc_fp > 0.85, f"float LeNet failed to learn: {acc_fp}"

    # refit_alpha mode (same 3-bit wire format); the paper-faithful Eq. 9
    # scalar's larger drop is characterized in benchmarks/bench_table3.py
    policy = QuantPolicy(
        base=QSQConfig(phi=4, group_size=16, refit_alpha=True), min_numel=256
    )
    qp = quantize_pytree(params, policy)
    deq = dequantize_pytree(qp, like=params)
    acc_q = cnn_accuracy(deq, LENET, imgs[:256], labels[:256])
    # paper: 98.68% -> 97.59% (a ~1.1 point drop); we allow a modest drop
    assert acc_q > acc_fp - 0.15, f"quantized acc dropped too far: {acc_fp}->{acc_q}"

    # +zeros claim
    from repro.core.qsq import QSQTensor

    total_z_fp, total_z_q, n = 0.0, 0.0, 0
    for leaf_fp, leaf_q in zip(
        jax.tree_util.tree_leaves(params),
        jax.tree_util.tree_leaves(
            qp.tree, is_leaf=lambda x: isinstance(x, QSQTensor)
        ),
        strict=True,
    ):
        if isinstance(leaf_q, QSQTensor):
            total_z_fp += float(zeros_fraction(leaf_fp))
            total_z_q += float(zeros_fraction(leaf_q.levels))
            n += 1
    assert n > 0 and total_z_q > total_z_fp


def test_fc_finetune_recovers_accuracy():
    """Table III row 3: retraining only the FC layers after quantization
    recovers (most of) the drop."""
    params, imgs, labels = _train_lenet()
    policy = QuantPolicy(base=QSQConfig(phi=1, group_size=16), min_numel=256)
    deq = dequantize_pytree(quantize_pytree(params, policy), like=params)
    acc_q = cnn_accuracy(deq, LENET, imgs[:256], labels[:256])

    # fine-tune FC only (convs frozen at quantized values)
    ocfg = AdamWConfig(lr=1e-3, weight_decay=0.0)
    opt = init_params(jax.random.PRNGKey(1), adamw_init_descs(cnn_descs(LENET)))

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(lambda p: cnn_loss(p, LENET, batch))(params)
        # zero conv grads => FC-only fine-tune
        grads = {"convs": jax.tree_util.tree_map(jnp.zeros_like, grads["convs"]),
                 "fcs": grads["fcs"]}
        params, opt, _ = adamw_update(ocfg, params, grads, opt)
        return params, opt, loss

    it = image_batches(imgs, labels, 64, seed=3)
    tuned = deq
    for _ in range(60):
        _, batch = next(it)
        tuned, opt, _ = step(tuned, opt, batch)
    acc_ft = cnn_accuracy(tuned, LENET, imgs[:256], labels[:256])
    assert acc_ft >= acc_q - 0.02  # never hurts, normally recovers


def test_e2e_train_quantize_transfer_serve():
    """The framework loop: train a small LM, QSQ-encode it (the channel
    artifact), decode on the 'edge', and serve tokens."""
    cfg = get_arch("smollm_135m", smoke=True)
    model = Model(cfg)
    data = LMDataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)

    from repro.train.trainer import Trainer, TrainerConfig

    tr = Trainer(model, TrainerConfig(total_steps=25, log_every=5,
                                      opt=AdamWConfig(lr=3e-3)),
                 lambda s: lm_batch(data, s))
    state, _ = tr.run()

    policy = QuantPolicy(base=QSQConfig(group_size=16), min_numel=512)
    wire = pack_pytree_wire(quantize_pytree(state.params, policy))
    eng = ServeEngine.from_wire(model, wire, ServeConfig(batch_slots=2))
    outs = eng.generate([[1, 2, 3]], max_new=5)
    assert len(outs[0]) == 5

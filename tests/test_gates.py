"""The CI gates are code, not YAML: unit tests for benchmarks.gates.

Each gate that used to live as an inline heredoc in the workflow (and
the new speculative-decode gate) is a plain function over parsed BENCH
records, so every failure mode — missing line, structural regression,
baseline regression, divergent tokens — is pinned here with synthetic
records instead of being exercised only when CI breaks for real.  Also
pins the qsqlint CLI contract the workflow's self-check step relies on:
--list-rules exits 0, config errors exit 2 (not 1, which means real
violations).
"""
import json

import pytest

from benchmarks import gates
from repro.analysis.__main__ import main as qsqlint_main

PS_OK = {
    "bench": "serve_plane_stream",
    "lo_over_hi_bytes": 0.3333,
    "all_hi": {"bytes_per_token": 12000.0},
    "all_lo": {"bytes_per_token": 4000.0},
}
PS_BASE = {"lo_over_hi_bytes": 0.3334}

OV_OK = {
    "bench": "serve_overload",
    "slo": 12.0,
    "slots": 4,
    "shed": {"4x": {"p90_latency": 10.0, "max_queue_depth": 6,
                    "shed_rate": 0.2, "reject_rate": 0.0}},
    "fifo": {"4x": {"p90_latency": 30.0}},
}

SP_OK = {
    "bench": "serve_speculative",
    "headline": "lo_k4",
    "tokens_exact": True,
    "hi_bytes_per_token": 16640.0,
    "lo_k4": {"acceptance_rate": 1.0, "bytes_per_token": 13226.7},
}
SP_BASE = {"min_acceptance_rate": 0.75, "max_spec_over_hi_bytes": 0.85}


def _ov(**patch4x):
    d = json.loads(json.dumps(OV_OK))
    d["shed"]["4x"].update(patch4x)
    return d


def _sp(**patch):
    d = json.loads(json.dumps(SP_OK))
    head = patch.pop("head", None)
    d.update(patch)
    if head:
        d["lo_k4"].update(head)
    return d


def test_parse_bench_lines_strips_prefix_and_blanks():
    lines = ["BENCH " + json.dumps(PS_OK), "", json.dumps(OV_OK) + "\n"]
    recs = gates.parse_bench_lines(lines)
    assert [r["bench"] for r in recs] == ["serve_plane_stream",
                                         "serve_overload"]


def test_extract_missing_bench_is_a_gate_error():
    with pytest.raises(gates.GateError, match="no serve_overload"):
        gates.extract([PS_OK], "serve_overload")


def test_plane_stream_gate_passes_and_catches_regressions():
    assert "ok" in gates.gate_plane_stream([PS_OK], PS_BASE)
    fat = dict(PS_OK, all_lo={"bytes_per_token": 12000.0})
    with pytest.raises(gates.GateError, match="not strictly below"):
        gates.gate_plane_stream([fat], PS_BASE)
    crept = dict(PS_OK, all_lo={"bytes_per_token": 4100.0})
    with pytest.raises(gates.GateError, match="regressed past"):
        gates.gate_plane_stream([crept], PS_BASE)


def test_overload_gate_passes_and_catches_every_failure_mode():
    assert "ok" in gates.gate_overload([OV_OK])
    with pytest.raises(gates.GateError, match="blows the"):
        gates.gate_overload([_ov(p90_latency=13.0)])
    vac = json.loads(json.dumps(OV_OK))
    vac["fifo"]["4x"]["p90_latency"] = 11.0
    with pytest.raises(gates.GateError, match="vacuous"):
        gates.gate_overload([vac])
    with pytest.raises(gates.GateError, match="queue depth"):
        gates.gate_overload([_ov(max_queue_depth=9)])
    with pytest.raises(gates.GateError, match="never exercised"):
        gates.gate_overload([_ov(shed_rate=0.0)])


def test_speculative_gate_passes_and_catches_every_failure_mode():
    assert "ok" in gates.gate_speculative([SP_OK], SP_BASE)
    with pytest.raises(gates.GateError, match="diverged"):
        gates.gate_speculative([_sp(tokens_exact=False)], SP_BASE)
    with pytest.raises(gates.GateError, match="acceptance rate"):
        gates.gate_speculative([_sp(head={"acceptance_rate": 0.5})],
                               SP_BASE)
    with pytest.raises(gates.GateError, match="not below plain hi"):
        gates.gate_speculative([_sp(head={"bytes_per_token": 17000.0})],
                               SP_BASE)
    with pytest.raises(gates.GateError, match="regressed past"):
        gates.gate_speculative([_sp(head={"bytes_per_token": 15000.0})],
                               SP_BASE)


def test_run_gate_writes_artifact_even_when_the_gate_fails(tmp_path):
    base = tmp_path / "baselines"
    base.mkdir()
    (base / "BENCH_serve_speculative.json").write_text(json.dumps(SP_BASE))
    bad = _sp(tokens_exact=False)
    with pytest.raises(gates.GateError):
        gates.run_gate("speculative", [bad], baseline_dir=base,
                       artifact_dir=tmp_path)
    art = tmp_path / "BENCH_serve_speculative.jsonl"
    assert json.loads(art.read_text()) == bad


def test_run_gate_missing_baseline_is_a_gate_error(tmp_path):
    with pytest.raises(gates.GateError, match="missing seeded baseline"):
        gates.run_gate("speculative", [SP_OK], baseline_dir=tmp_path)


def test_cli_end_to_end_pass_and_fail(tmp_path, capsys):
    lines = tmp_path / "bench-lines.jsonl"
    lines.write_text("BENCH " + json.dumps(SP_OK) + "\n")
    base = tmp_path / "baselines"
    base.mkdir()
    (base / "BENCH_serve_speculative.json").write_text(json.dumps(SP_BASE))
    argv = ["speculative", "--bench-lines", str(lines),
            "--baselines-dir", str(base), "--artifact-dir", str(tmp_path)]
    assert gates.main(argv) == 0
    assert "ok" in capsys.readouterr().out
    lines.write_text("BENCH " + json.dumps(_sp(tokens_exact=False)) + "\n")
    assert gates.main(argv) == 1
    assert "GATE FAIL" in capsys.readouterr().err
    assert gates.main(["speculative", "--bench-lines",
                       str(tmp_path / "nope.jsonl")]) == 1


def test_repo_baselines_satisfy_the_gate_schemas():
    """The seeded baseline files carry every key their gate reads."""
    ps = gates.load_baseline("BENCH_serve_plane_stream")
    assert 0 < ps["lo_over_hi_bytes"] <= 1
    sp = gates.load_baseline("BENCH_serve_speculative")
    assert 0 < sp["min_acceptance_rate"] <= 1
    assert 0 < sp["max_spec_over_hi_bytes"] < 1


def test_qsqlint_cli_exit_codes():
    """0 for --list-rules, 2 for a config error — never conflated with
    1 (real violations), which CI treats as a lint failure."""
    assert qsqlint_main(["--list-rules"]) == 0
    assert qsqlint_main(["--select", "NOPE", "src"]) == 2

"""Packed-weight (bit-plane) serving path: models.layers.W + quant.packed."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import Model
from repro.models.base import init_params
from repro.quant.packed import pack_params, packed_bits_report, packed_param_descs


@pytest.mark.parametrize("arch", ["deepseek_7b", "mamba2_1_3b", "mixtral_8x22b"])
def test_packed_decode_close_to_dense(arch):
    cfg = get_arch(arch, smoke=True)
    model = Model(cfg)
    descs = model.param_descs()
    params = init_params(jax.random.PRNGKey(0), descs)
    packed = pack_params(params, descs, group_size=16, min_numel=1024)

    tok = jnp.ones((2, 1), jnp.int32)
    cache_a = init_params(jax.random.PRNGKey(1), model.cache_descs(2, 8))
    cache_b = init_params(jax.random.PRNGKey(1), model.cache_descs(2, 8))
    l_dense, _ = model.decode(params, cache_a, {"tokens": tok})
    l_packed, _ = model.decode(packed, cache_b, {"tokens": tok})
    corr = float(jnp.corrcoef(l_dense.reshape(-1), l_packed.reshape(-1))[0, 1])
    assert corr > 0.7, f"packed decode diverged: corr={corr}"
    assert not bool(jnp.isnan(l_packed).any())


def test_packed_W_exact_roundtrip():
    """W() must invert pack exactly (the quantized values, not the originals)."""
    from repro.core import codec
    from repro.core.qsq import QSQConfig, dequantize, quantize
    from repro.models.layers import W
    from repro.quant.store import PackedWeight

    w = jax.random.normal(jax.random.PRNGKey(2), (64, 32)) * 0.1
    q = quantize(w, QSQConfig(phi=4, group_size=16, refit_alpha=True))
    packed = PackedWeight(planes=codec.pack_bitplane(q.codes()), scales=q.scales,
                          group_size=16, phi=4, rest_ndim=1)
    np.testing.assert_allclose(
        np.asarray(W(packed)), np.asarray(dequantize(q)), rtol=1e-6
    )


def test_packed_descs_shapes_match_arrays():
    cfg = get_arch("deepseek_7b", smoke=True)
    model = Model(cfg)
    descs = model.param_descs()
    params = init_params(jax.random.PRNGKey(0), descs)
    packed = pack_params(params, descs, group_size=16, min_numel=1024)
    pdescs = packed_param_descs(descs, group_size=16, min_numel=1024)

    flat_a = jax.tree_util.tree_flatten_with_path(packed)[0]
    flat_d = {jax.tree_util.keystr(p): d
              for p, d in jax.tree_util.tree_flatten_with_path(
                  pdescs, is_leaf=lambda x: hasattr(x, "axes"))[0]}
    for path, arr in flat_a:
        key = jax.tree_util.keystr(path)
        assert key in flat_d, key
        assert tuple(arr.shape) == tuple(flat_d[key].shape), key


def test_packed_report_savings():
    full = Model(get_arch("deepseek_7b"))
    rep = packed_bits_report(full.param_descs(), group_size=64)
    assert rep["n_packed_leaves"] >= 5
    assert 0.5 < rep["savings"] < 0.85  # most of the model at ~3.5 bits


def test_wo_and_embeddings_stay_dense():
    cfg = get_arch("deepseek_7b", smoke=True)
    model = Model(cfg)
    descs = model.param_descs()
    params = init_params(jax.random.PRNGKey(0), descs)
    packed = pack_params(params, descs, group_size=16, min_numel=1024)
    from repro.quant.store import PackedWeight

    assert not isinstance(packed["blocks"]["attn"]["wo"], PackedWeight)
    assert not isinstance(packed["embed"]["tok"], PackedWeight)
    assert isinstance(packed["embed"]["head"], PackedWeight)  # head IS packed

"""Per-request quality: mixed-tier continuous batching invariants.

The tentpole contract under test —

* ``submit(prompt, max_new, quality=t)`` serves THAT request at tier t:
  its tokens are identical to a single-tier engine (physically
  plane-truncated params) serving the prompt alone at t, even while batch
  mates decode at other tiers in the same fixed-width dispatch;
* a randomized submit/step/poll schedule with mixed tiers stays
  request-for-request identical to the per-request static-path oracle
  (scheduler fuzz);
* tier changes are mask flips: the dispatch counters (trace-time only)
  stay frozen across mixed-tier admissions, evictions and ``set_quality``;
* ``set_quality`` on a per-request engine is just the default for
  quality-less submissions — no drain, live requests keep their tier.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.configs.base import ArchConfig
from repro.models.api import Model
from repro.models.base import init_params
from repro.quant import tree_bits_report
from repro.serve import ServeConfig, ServeEngine


def _model_and_params():
    cfg = ArchConfig(name="smollm-like", family="dense", n_layers=2,
                     d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256,
                     dtype=jnp.float32, remat=False)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_descs())
    return model, params


@pytest.fixture(scope="module")
def artifact():
    model, params = _model_and_params()
    return api.compress(model, params), model, params


@pytest.fixture(scope="module")
def solo_oracle(artifact):
    """(prompt, max_new, tier) -> solo tokens from a SINGLE-TIER engine:
    per_request=False forces the physically plane-truncated param layout,
    so the oracle shares nothing with the per-slot mask path but the
    wire."""
    art, _, _ = artifact
    engines = {}
    memo = {}

    def run(prompt, max_new, tier):
        key = (tuple(prompt), max_new, tier)
        if key not in memo:
            if tier not in engines:
                engines[tier] = art.engine(quality=tier, per_request=False,
                                           batch_slots=1, continuous=False)
            memo[key] = engines[tier].generate([list(prompt)],
                                               max_new=max_new)[0]
        return memo[key]

    return run


def test_engine_is_per_request_by_default(artifact):
    art, _, _ = artifact
    eng = art.engine(quality="hi", batch_slots=2, max_prompt=8, max_len=24)
    assert eng.per_request_quality
    assert eng.tier_names == art.quality_names()
    # forcing the single-tier layout still works, and actually truncates
    lo = art.engine(quality="lo", per_request=False, batch_slots=2)
    assert not lo.per_request_quality
    assert (tree_bits_report(lo.params)["bits"]
            < tree_bits_report(eng.params)["bits"])


def test_mixed_tier_tokens_match_solo_single_tier(artifact, solo_oracle):
    """ACCEPTANCE: one mixed-tier continuous batch emits, per request,
    tokens identical to a single-tier engine serving that prompt alone at
    that tier."""
    art, _, _ = artifact
    eng = art.engine(quality="hi", batch_slots=3, max_prompt=8, max_len=24)
    prompts = [[1, 2, 3], [9, 9], [100, 42, 7]]
    tiers = ["hi", "mid", "lo"]
    rids = [eng.submit(p, max_new=6, quality=q)
            for p, q in zip(prompts, tiers, strict=True)]
    out = eng.run_until_drained()
    for p, q, r in zip(prompts, tiers, rids, strict=True):
        assert out[r].tokens == solo_oracle(p, 6, q), q
    # tiers must actually disagree somewhere, or the assertion is vacuous
    assert len({tuple(solo_oracle([1, 2, 3], 6, q))
                for q in art.quality_names()}) > 1


def test_mid_stream_admission_at_other_tier(artifact, solo_oracle):
    """A lo request admitted MID-DECODE of a hi request: both exact, and
    the hi slot's tokens are unperturbed by the tier mix."""
    art, _, _ = artifact
    eng = art.engine(quality="hi", batch_slots=2, max_prompt=8, max_len=32)
    r_hi = eng.submit([1, 2, 3], max_new=10, quality="hi")
    for _ in range(4):
        eng.step()
    r_lo = eng.submit([9, 9], max_new=6, quality="lo")
    out = eng.run_until_drained()
    assert out[r_hi].tokens == solo_oracle([1, 2, 3], 10, "hi")
    assert out[r_lo].tokens == solo_oracle([9, 9], 6, "lo")


def test_scheduler_fuzz_mixed_tiers_vs_solo_oracle(artifact, solo_oracle,
                                                   no_retrace):
    """Randomized submit/step/poll schedules with mixed tiers: every
    result token-identical to its solo single-tier oracle, across slot
    reuse, queueing and interleaved polls — and the whole schedule traces
    once per demand pattern (counters frozen after warmup)."""
    art, _, _ = artifact
    rng = np.random.RandomState(1234)
    tier_names = art.quality_names()
    eng = art.engine(quality="mid", batch_slots=2, max_prompt=6, max_len=16)

    # warmup: trace admit + decode programs once PER TIER — demand (the
    # min live tier index) is a static jit arg, so a solo request at each
    # tier covers every demand pattern either program can see; any mixed
    # batch's demand is one of these
    for q in tier_names:
        eng.submit([7, 7], max_new=2, quality=q)
        eng.run_until_drained()

    expected, results, live = {}, {}, []
    # demand-driven streaming keeps retraces bounded by the TIER COUNT,
    # not the schedule: all demands warmed above, so the whole fuzz run
    # must trace nothing new
    with no_retrace(eng._cont_step, eng._admit):
        for _ in range(40):
            op = rng.choice(["submit", "step", "poll"], p=[0.4, 0.45, 0.15])
            if op == "submit":
                prompt = rng.randint(1, 256, size=rng.randint(1, 5)).tolist()
                max_new = int(rng.choice([2, 4]))
                quality = (None if rng.rand() < 0.25
                           else str(rng.choice(tier_names)))
                rid = eng.submit(prompt, max_new=max_new, quality=quality)
                expected[rid] = (prompt, max_new, quality or eng.quality)
                live.append(rid)
            elif op == "step":
                eng.step()
            else:
                if live and rng.rand() < 0.5:
                    rid = live[int(rng.randint(len(live)))]
                    st = eng.poll(rid)  # structured, idempotent
                    if st.done:
                        results[rid] = st
                        live.remove(rid)
                else:
                    got = eng.poll()
                    results.update(got)
                    live = [r for r in live if r not in got]
        results.update(eng.run_until_drained())
    # one trace per distinct demand, all during warmup
    assert eng._cont_step._cache_size() == len(tier_names)
    assert eng._admit._cache_size() == len(tier_names)
    assert len(results) == len(expected) > 10
    for rid, (prompt, max_new, tier) in expected.items():
        assert results[rid].tokens == solo_oracle(prompt, max_new, tier), \
            (rid, tier, prompt)
    # the fuzz must actually have mixed tiers
    assert len({t for _, _, t in expected.values()}) == len(tier_names)


def test_set_quality_mid_stream_changes_default_only(artifact, solo_oracle):
    """Per-request engines re-dial WITHOUT draining: live requests keep
    the tier they were admitted at; only future submissions see the new
    default."""
    art, _, _ = artifact
    eng = art.engine(quality="hi", batch_slots=2, max_prompt=8, max_len=24)
    r_before = eng.submit([5, 6], max_new=4)       # default: hi
    eng.step()                                     # r_before is decoding
    eng.set_quality("lo")                          # no drain required
    r_after = eng.submit([5, 6], max_new=4)        # default: lo
    out = eng.run_until_drained()
    assert out[r_before].tokens == solo_oracle([5, 6], 4, "hi")
    assert out[r_after].tokens == solo_oracle([5, 6], 4, "lo")
    with pytest.raises(KeyError, match="unknown quality tier"):
        eng.set_quality("ultra")


def test_generate_qualities_kwarg(artifact, solo_oracle):
    art, _, _ = artifact
    eng = art.engine(quality="hi", batch_slots=3)
    prompts = [[1, 2, 3], [9, 9], [100, 42, 7]]
    outs = eng.generate(prompts, max_new=5, qualities=["lo", "hi", "mid"])
    for p, q, o in zip(prompts, ["lo", "hi", "mid"], outs, strict=True):
        assert o == solo_oracle(p, 5, q)
    # one name applies to all
    outs = eng.generate(prompts[:2], max_new=5, qualities="mid")
    assert outs == [solo_oracle(p, 5, "mid") for p in prompts[:2]]
    with pytest.raises(ValueError, match="one tier name per prompt"):
        eng.generate(prompts, max_new=5, qualities=["hi"])


def test_submit_quality_validation(artifact):
    art, model, params = artifact
    eng = art.engine(quality="hi", batch_slots=2, max_prompt=8, max_len=24)
    with pytest.raises(KeyError, match="unknown quality tier"):
        eng.submit([1, 2], quality="ultra")
    # single-tier engines reject per-request tiers outright
    plain = ServeEngine(model, params,
                        ServeConfig(batch_slots=2, max_prompt=8, max_len=24))
    with pytest.raises(ValueError, match="per-request quality"):
        plain.submit([1, 2], quality="hi")
    with pytest.raises(ValueError, match="per-request"):
        art.engine(quality="hi", per_request=True, continuous=False)


def test_static_path_rejects_qualities(artifact):
    art, _, _ = artifact
    stat = art.engine(quality="hi", per_request=False, batch_slots=2,
                      continuous=False)
    with pytest.raises(ValueError, match="continuous"):
        stat.generate([[1, 2]], max_new=4, qualities="lo")


def test_rankless_artifact_not_per_request(artifact):
    """A bare wire (no sensitivity ranking) cannot resolve tier drop maps;
    the engine must fall back to the single-tier layout, not silently
    serve full quality under every tier name."""
    from repro.quant.artifact import EdgeArtifact

    art, model, _ = artifact
    bare = EdgeArtifact(wire=art.wire, arch_config=model.cfg)
    eng = bare.engine(quality="hi", batch_slots=2, max_prompt=8, max_len=24)
    assert not eng.per_request_quality
    with pytest.raises(ValueError, match="per-request quality"):
        bare.engine(quality="hi", per_request=True,
                    batch_slots=2, max_prompt=8, max_len=24)

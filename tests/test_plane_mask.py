"""Per-row plane masks: the kernel-level contract of per-request quality.

The tentpole invariant: ``PackedWeight.matmul(x, plane_mask=m)`` computes
row b EXACTLY as ``truncate(drop_b).matmul(x)[b]`` would — a dropped plane
is a masked term of the in-kernel unpack, so a quality tier is a per-row
mask flip, not a param-tree swap.  Checked bit-for-bit across the GEMV,
GEMM and XLA-ref dispatch routes, padded (ragged) shapes included, and the
per-weight truncation error stays within the documented
``max_level_delta(drop) * alpha`` bound.

Property tests run under hypothesis when it is installed; on a clean
interpreter they fall back to a fixed seed sweep of the same checks.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on the environment
    HAS_HYPOTHESIS = False

from repro.core.qsq import QSQConfig, quantize
from repro.kernels import dispatch
from repro.kernels.ref import MASK_VARIANTS
from repro.quant.store import (
    QSQWeight,
    max_level_delta,
    plane_mask_for_drop,
    set_packed_matmul_kernel,
)


def _packed(k, n, g, seed):
    rng = np.random.RandomState(seed)
    w = jnp.asarray(rng.randn(k, n), jnp.float32)
    q = QSQWeight.from_tensor(
        quantize(w, QSQConfig(group_size=g, refit_alpha=True)), rest_ndim=1
    )
    return q.pack()


def _check_masked_rows_match_truncated(m, kmul, n, g, seed, use_kernel):
    """Each masked-matmul row is bit-identical to the whole-weight
    truncation at that row's drop, on the route the dispatcher picks."""
    k = 32 * kmul
    if k % g:
        g = 32
    pw = _packed(k, n, g, seed)
    rng = np.random.RandomState(seed + 1)
    x = jnp.asarray(rng.randn(m, k), jnp.float32)
    drops = rng.randint(0, 3, size=m)
    masks = jnp.asarray([plane_mask_for_drop(int(d)) for d in drops], jnp.int32)
    set_packed_matmul_kernel(use_kernel)
    try:
        got = np.asarray(pw.matmul(x, plane_mask=masks))
        for d in (0, 1, 2):
            rows = np.where(drops == d)[0]
            if len(rows) == 0:
                continue
            want = np.asarray(pw.truncate(int(d)).matmul(x))
            np.testing.assert_array_equal(got[rows], want[rows])
    finally:
        set_packed_matmul_kernel(True)


def _check_truncation_error_bound(kmul, n, g, seed):
    """|truncate(drop) - full| <= max_level_delta(drop) * alpha, per group."""
    k = 32 * kmul
    if k % g:
        g = 32
    pw = _packed(k, n, g, seed)
    full = np.asarray(pw.as_dense())
    scales = np.asarray(pw.scales)
    for drop in (1, 2):
        err = np.abs(np.asarray(pw.truncate(drop).as_dense()) - full)
        err_g = err.reshape(scales.shape[0], pw.group_size, -1)
        bound = max_level_delta(drop) * np.abs(scales[:, None, :]) + 1e-6
        assert np.all(err_g <= bound), (drop, float((err_g - bound).max()))


if HAS_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(
        m=st.integers(1, 24),
        kmul=st.integers(1, 4),
        n=st.integers(8, 200),
        g=st.sampled_from([16, 32, 64]),
        seed=st.integers(0, 2**16),
        use_kernel=st.booleans(),
    )
    def test_masked_rows_match_truncated(m, kmul, n, g, seed, use_kernel):
        _check_masked_rows_match_truncated(m, kmul, n, g, seed, use_kernel)

    @settings(max_examples=10, deadline=None)
    @given(
        kmul=st.integers(1, 4),
        n=st.integers(8, 128),
        g=st.sampled_from([16, 32, 64]),
        seed=st.integers(0, 2**16),
    )
    def test_truncation_error_bound(kmul, n, g, seed):
        _check_truncation_error_bound(kmul, n, g, seed)

else:  # pragma: no cover - fallback sweep on hypothesis-less interpreters

    @pytest.mark.parametrize("m,kmul,n,g,seed,use_kernel", [
        (1, 1, 8, 16, 0, True),
        (4, 2, 48, 16, 1, True),
        (3, 4, 100, 32, 2, True),
        (24, 3, 130, 64, 3, True),
        (8, 2, 64, 16, 4, False),
        (17, 1, 200, 32, 5, False),
    ])
    def test_masked_rows_match_truncated(m, kmul, n, g, seed, use_kernel):
        _check_masked_rows_match_truncated(m, kmul, n, g, seed, use_kernel)

    @pytest.mark.parametrize("kmul,n,g,seed", [
        (1, 8, 16, 0), (2, 48, 32, 1), (4, 128, 64, 2),
    ])
    def test_truncation_error_bound(kmul, n, g, seed):
        _check_truncation_error_bound(kmul, n, g, seed)


# --------------------------------------------------------------------------
# Fixed-case contracts (not property-swept)
# --------------------------------------------------------------------------
def test_mask_variants_cover_all_drops():
    assert tuple(plane_mask_for_drop(d) for d in (0, 1, 2)) == MASK_VARIANTS


def test_masked_call_counts_and_routes_like_unmasked():
    """The masked operand must not change the dispatch plan — same route,
    same tiling, one extra ':masked' counter."""
    pw = _packed(64, 48, 16, 0)
    x = jnp.ones((4, 64), jnp.float32)
    masks = jnp.full((4,), plane_mask_for_drop(1), jnp.int32)
    dispatch.reset_counters()
    pw.matmul(x)
    unmasked = dict(dispatch.counters)
    dispatch.reset_counters()
    pw.matmul(x, plane_mask=masks)
    masked = dict(dispatch.counters)
    route = dispatch.plan(4, 64, 48, 16).route
    assert unmasked[route] == 1 and masked[route] == 1
    assert masked[f"{route}:masked"] == 1
    dispatch.reset_counters()


def test_plane_mask_broadcasts_over_seq_dim():
    """(B,) masks on a (B, S, K) x apply per slot across the sequence —
    the prefill case."""
    pw = _packed(64, 48, 16, 7)
    rng = np.random.RandomState(9)
    x = jnp.asarray(rng.randn(2, 5, 64), jnp.float32)
    masks = jnp.asarray([plane_mask_for_drop(0), plane_mask_for_drop(2)],
                        jnp.int32)
    got = np.asarray(pw.matmul(x, plane_mask=masks))
    np.testing.assert_array_equal(got[0], np.asarray(pw.matmul(x[0])))
    np.testing.assert_array_equal(
        got[1], np.asarray(pw.truncate(2).matmul(x[1])))


def test_plane_mask_bad_shape_raises():
    pw = _packed(64, 48, 16, 8)
    x = jnp.ones((4, 64), jnp.float32)
    with pytest.raises(ValueError, match="plane_mask"):
        pw.matmul(x, plane_mask=jnp.zeros((3,), jnp.int32))


def test_plane_mask_for_drop_validates():
    with pytest.raises(ValueError, match="drop"):
        plane_mask_for_drop(3)

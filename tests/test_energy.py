"""Eq. 11/12 memory & energy model + roofline terms."""

from repro.core import energy
from repro.models.cnn import LENET, conv_layer_shapes


def test_eq11():
    assert energy.nbits_unquantized(1000) == 32_000


def test_eq12_general():
    # 1024 elements, groups of 16 -> 64 scalars
    assert energy.nbits_quantized(1024, 16, 3) == 3 * 1024 + 32 * 64


def test_eq12_conv_faithful():
    # paper reading: one scalar per (h, w, c) position, vector across filters
    bits = energy.nbits_conv_layer(5, 5, 6, 16, group_size=None)
    assert bits == 3 * 5 * 5 * 6 * 16 + 5 * 5 * 6 * 32


def test_memory_savings_monotone_in_group():
    s = [energy.memory_savings(2**14, g) for g in (2, 4, 8, 16, 32, 64)]
    assert all(b > a for a, b in zip(s, s[1:], strict=False))
    # asymptote: 1 - 3/32 = 0.90625
    assert s[-1] < 1 - 3 / 32


def test_lenet_savings_near_paper():
    """The paper reports 82.49% LeNet parameter reduction; with the conv
    layers encoded at paper-faithful grouping plus FC at N=16 we land in the
    same regime (>75%)."""
    layers = conv_layer_shapes(LENET)
    rep = energy.model_savings(layers, group_size=16, bit_encoding=3)
    assert 0.75 < rep["memory_savings"] < 0.92


def test_energy_2bit_beats_3bit():
    """Fig. 10: ternary (2-bit) always saves slightly more energy."""
    for g in (4, 16, 64):
        assert energy.energy_savings(2**16, g, 2) > energy.energy_savings(2**16, g, 3)


def test_roofline_terms():
    rt = energy.roofline_terms(
        hlo_flops=197e12 * 256,  # exactly 1s of compute on 256 chips
        hlo_bytes=819e9 * 256 * 0.5,
        collective_bytes=50e9 * 256 * 0.25,
        n_chips=256,
    )
    assert abs(rt["compute_s"] - 1.0) < 1e-9
    assert abs(rt["memory_s"] - 0.5) < 1e-9
    assert abs(rt["collective_s"] - 0.25) < 1e-9
    assert rt["dominant"] == "compute"
    assert abs(rt["roofline_fraction"] - 1.0) < 1e-9


def test_dram_energy_paper_constant():
    assert energy.dram_energy_pj(32) == 6400.0

"""Fig. 11: distribution of non-zero CSD digits in trained model weights
(the paper used AlexNet via MATLAB fi; we use our trained LeNet + a smoke
transformer) + the partial-product savings of the quality-scalable multiplier.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import train_cnn
from repro.core.csd import csd_nonzero_histogram, partial_product_savings
from repro.models.cnn import LENET


def main(verbose: bool = True):
    t0 = time.time()
    params, *_ = train_cnn(LENET, steps=120)
    weights = np.concatenate([
        np.asarray(a).reshape(-1)
        for a in jax.tree_util.tree_leaves(params) if a.ndim >= 2
    ])
    hist = np.asarray(csd_nonzero_histogram(weights))
    total = hist.sum()
    rows = []
    cum = 0
    for k in range(0, 12):
        cum += int(hist[k])
        rows.append((f"fig11/csd_digits_le_{k}", cum / total))
    for k in (1, 2, 3, 4):
        s = float(partial_product_savings(weights, k))
        rows.append((f"fig11/pp_savings_k{k}", s))
    dt = time.time() - t0
    if verbose:
        print("Fig. 11 — CSD non-zero digit distribution (trained LeNet):")
        for name, v in rows:
            print(f"  {name:28s} {v * 100:.2f}%")
        print("  paper claim: few non-zeros represent most values -> "
              f"P(digits<=4)={sum(hist[:5]) / total:.3f}")
    return [(name, dt / len(rows) * 1e6, f"{v:.4f}") for name, v in rows]


if __name__ == "__main__":
    main()

"""Serve-latency benchmark: dense vs packed engines, plus the quality dial.

Builds a smollm-class (32-aligned) model, compresses it into an
EdgeArtifact, and times `ServeEngine.generate` for (a) the exact dense
engine, (b) the wire engine with full dense decode at load, and (c) the
wire engine serving packed bit-planes end-to-end — then sweeps the
artifact's quality tiers, where lower tiers drop LSB bit-planes from the
least-sensitive layers without re-quantizing.  On this CPU container the
packed matmuls run the Pallas kernel in interpret mode, so WALL time is
meaningless as a TPU prediction; the derived columns carry the structural
serving win: bits held per weight (= HBM residency / weight-stream bytes
on the target) and the packed-leaf count.  Emits one BENCH json line for
the engine comparison and one per quality tier, plus the standard
(name, us_per_call, derived) rows for benchmarks.run.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit_us
from repro import api
from repro.configs.base import ArchConfig
from repro.models.api import Model
from repro.models.base import init_params
from repro.quant import tree_bits_report
from repro.serve import ServeConfig, ServeEngine
from repro.train.step import make_cache_prefill_step

PROMPTS = [[1, 2, 3], [9, 9], [100, 42, 7, 8]]
MAX_NEW = 16
PREFILL_LEN = 16  # acceptance: one-dispatch beats scan at prompt len >= 16


def _model():
    cfg = ArchConfig(name="smollm-bench", family="dense", n_layers=2,
                     d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256,
                     dtype=jnp.float32, remat=False)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_descs())
    return model, params


def _tok_per_s(engine) -> tuple[float, float]:
    """(tokens/s, us/token) for a generate() call, after one warmup."""
    engine.generate(PROMPTS, max_new=MAX_NEW)  # warmup: jit both scans
    n = len(PROMPTS) * MAX_NEW
    t0 = time.time()
    engine.generate(PROMPTS, max_new=MAX_NEW)
    dt = time.time() - t0
    return n / dt, dt / n * 1e6


def _measure(name, eng, params, rows, stats, verbose):
    tok_s, us_tok = _tok_per_s(eng)
    rep = tree_bits_report(eng.params)
    n_w = sum(int(jnp.size(l)) for l in jax.tree_util.tree_leaves(params))
    bits_per_weight = rep["bits"] / n_w
    rows.append((f"serve/{name}", us_tok,
                 f"tok_s={tok_s:.1f}|bits_per_weight={bits_per_weight:.2f}"
                 f"|packed_leaves={eng.n_packed_leaves}"))
    stats[name] = {
        "tok_s": round(tok_s, 2),
        "us_per_tok": round(us_tok, 1),
        "weight_bits": rep["bits"],
        "bits_per_weight": round(bits_per_weight, 2),
        "packed_leaves": eng.n_packed_leaves,
    }
    if verbose:
        print(f"  {name}: {tok_s:.1f} tok/s ({us_tok:.0f} us/tok), "
              f"{bits_per_weight:.2f} bits/weight, "
              f"{eng.n_packed_leaves} packed leaves")
    return stats[name]


def _prefill_compare(model, params, plen: int = PREFILL_LEN, slots: int = 4):
    """(fused_us, scan_us) per prompt batch at prompt length ``plen``.

    Fused = the engine's ONE-DISPATCH full-sequence prefill (packed weights
    stream once per prompt).  Scan = the legacy per-token lax.scan over
    decode steps (weights stream once per TOKEN) — kept here only as the
    baseline the tentpole replaced."""
    cache = init_params(jax.random.PRNGKey(0), model.cache_descs(slots, plen + 2))
    toks = jnp.asarray(
        np.random.default_rng(0).integers(1, model.cfg.vocab, (slots, plen)),
        jnp.int32,
    )
    lens = jnp.full((slots,), plen, jnp.int32)

    fused = jax.jit(make_cache_prefill_step(model))

    def scan_prefill(params, cache, tokens):
        def body(cache, tok):
            logits, cache = model.decode(params, cache, {"tokens": tok})
            return cache, logits[:, -1, :]

        cache, logits = jax.lax.scan(
            body, cache, jnp.moveaxis(tokens, 1, 0)[:, :, None]
        )
        return cache, logits[-1]

    scan = jax.jit(scan_prefill)
    fused_us = timeit_us(fused, params, cache, toks, lens, warmup=1, iters=5)
    scan_us = timeit_us(scan, params, cache, toks, warmup=1, iters=5)
    return fused_us, scan_us


def main(verbose: bool = True, quick: bool = False):
    del quick  # the serve bench is already its own smallest configuration
    model, params = _model()
    artifact = api.compress(model, params)

    engines = {
        "dense_exact": ServeEngine(model, params, ServeConfig(batch_slots=4)),
        "wire_dense": artifact.engine(quality="hi", batch_slots=4,
                                      packed=False),
        "wire_packed": artifact.engine(quality="hi", batch_slots=4),
    }

    rows = []
    stats = {}
    for name, eng in engines.items():
        _measure(name, eng, params, rows, stats, verbose)

    # tokens must agree bit-exactly across the two wire engines
    outs = [eng.generate(PROMPTS, max_new=8) for eng in
            (engines["wire_dense"], engines["wire_packed"])]
    assert outs[0] == outs[1], "packed engine diverged from dense decode"

    # per-prompt prefill cost on the packed tree: the one-dispatch prefill
    # streams every packed weight once per prompt; the scan streamed them
    # once per token.
    fused_us, scan_us = _prefill_compare(model, engines["wire_packed"].params)
    rows.append(("serve/prefill_one_dispatch", fused_us,
                 f"scan_us={scan_us:.0f}|len={PREFILL_LEN}"
                 f"|speedup={scan_us / max(fused_us, 1e-9):.2f}x"))
    if verbose:
        print(f"  prefill(len={PREFILL_LEN}): one-dispatch {fused_us:.0f}us "
              f"vs scan {scan_us:.0f}us "
              f"({scan_us / max(fused_us, 1e-9):.2f}x)")

    print("BENCH " + json.dumps({"bench": "serve",
                                 "prompts": len(PROMPTS),
                                 "max_new": MAX_NEW,
                                 "prefill_len": PREFILL_LEN,
                                 "prefill_us": round(fused_us, 1),
                                 "scan_prefill_us": round(scan_us, 1),
                                 **stats}))

    # quality-tier sweep: one engine per tier from the SAME artifact, lower
    # tiers realized by LSB plane truncation (never a re-quantize); one
    # BENCH line per tier so the perf trajectory captures the
    # quality/throughput trade-off.  'hi' IS the wire_packed engine — reuse
    # it instead of repacking and re-jitting an identical tree.
    for tier in artifact.quality_names():
        drop = artifact.drop_map(tier)
        eng = (engines["wire_packed"] if not drop
               else artifact.engine(quality=tier, batch_slots=4))
        tier_stats = _measure(f"tier_{tier}", eng, params, rows, stats,
                              verbose)
        print("BENCH " + json.dumps({
            "bench": "serve_quality",
            "tier": tier,
            "truncated_leaves": len(drop),
            "tok_s": tier_stats["tok_s"],
            "weight_bits": tier_stats["weight_bits"],
            "packed_leaves": tier_stats["packed_leaves"],
        }))

    return rows


if __name__ == "__main__":
    main()

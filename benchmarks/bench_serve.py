"""Serve-latency benchmark: dense engine vs packed-wire engine.

Builds a smollm-class (32-aligned) model, ships it through the QSQ wire,
and times `ServeEngine.generate` for (a) the exact dense engine, (b) the
wire engine with full dense decode at load, and (c) the wire engine serving
packed bit-planes end-to-end.  On this CPU container the packed matmuls run
the Pallas kernel in interpret mode, so its WALL time is meaningless as a
TPU prediction; the derived columns carry the structural serving win: bits
held per weight (= HBM residency / weight-stream bytes on the target) and
the packed-leaf count.  Emits one BENCH json line for dashboard scraping,
plus the standard (name, us_per_call, derived) rows for benchmarks.run.
"""
from __future__ import annotations

import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.policy import QuantPolicy
from repro.core.qsq import QSQConfig
from repro.models.api import Model
from repro.models.base import init_params
from repro.quant import pack_pytree_wire, quantize_pytree, tree_bits_report
from repro.serve import ServeConfig, ServeEngine

PROMPTS = [[1, 2, 3], [9, 9], [100, 42, 7, 8]]
MAX_NEW = 16


def _model():
    cfg = ArchConfig(name="smollm-bench", family="dense", n_layers=2,
                     d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256,
                     dtype=jnp.float32, remat=False)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_descs())
    return model, params


def _tok_per_s(engine) -> tuple[float, float]:
    """(tokens/s, us/token) for a generate() call, after one warmup."""
    engine.generate(PROMPTS, max_new=MAX_NEW)  # warmup: jit both scans
    n = len(PROMPTS) * MAX_NEW
    t0 = time.time()
    engine.generate(PROMPTS, max_new=MAX_NEW)
    dt = time.time() - t0
    return n / dt, dt / n * 1e6


def main(verbose: bool = True):
    model, params = _model()
    descs = model.param_descs()
    policy = QuantPolicy(base=QSQConfig(group_size=16, refit_alpha=True),
                         min_numel=512)
    wire = pack_pytree_wire(quantize_pytree(params, policy, descs))

    engines = {
        "dense_exact": ServeEngine(model, params, ServeConfig(batch_slots=4)),
        "wire_dense": ServeEngine.from_wire(
            model, wire, ServeConfig(batch_slots=4, packed=False)),
        "wire_packed": ServeEngine.from_wire(
            model, wire, ServeConfig(batch_slots=4)),
    }

    rows = []
    stats = {}
    for name, eng in engines.items():
        tok_s, us_tok = _tok_per_s(eng)
        rep = tree_bits_report(eng.params)
        n_w = sum(int(jnp.size(l)) for l in jax.tree_util.tree_leaves(params))
        bits_per_weight = rep["bits"] / n_w
        rows.append((f"serve/{name}", us_tok,
                     f"tok_s={tok_s:.1f}|bits_per_weight={bits_per_weight:.2f}"
                     f"|packed_leaves={eng.n_packed_leaves}"))
        stats[name] = {
            "tok_s": round(tok_s, 2),
            "us_per_tok": round(us_tok, 1),
            "bits_per_weight": round(bits_per_weight, 2),
            "packed_leaves": eng.n_packed_leaves,
        }
        if verbose:
            print(f"  {name}: {tok_s:.1f} tok/s ({us_tok:.0f} us/tok), "
                  f"{bits_per_weight:.2f} bits/weight, "
                  f"{eng.n_packed_leaves} packed leaves")

    # tokens must agree bit-exactly across all three engines
    outs = [eng.generate(PROMPTS, max_new=8) for eng in
            (engines["wire_dense"], engines["wire_packed"])]
    assert outs[0] == outs[1], "packed engine diverged from dense decode"

    print("BENCH " + json.dumps({"bench": "serve",
                                 "prompts": len(PROMPTS),
                                 "max_new": MAX_NEW,
                                 **stats}))
    return rows


if __name__ == "__main__":
    main()

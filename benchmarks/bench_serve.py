"""Serve-latency benchmark: dense vs packed engines, plus the quality dial.

Builds a smollm-class (32-aligned) model, compresses it into an
EdgeArtifact, and times `ServeEngine.generate` for (a) the exact dense
engine, (b) the wire engine with full dense decode at load, and (c) the
wire engine serving packed bit-planes end-to-end — then sweeps the
artifact's quality tiers, where lower tiers drop LSB bit-planes from the
least-sensitive layers without re-quantizing.  On this CPU container the
packed matmuls run the Pallas kernel in interpret mode, so WALL time is
meaningless as a TPU prediction; the derived columns carry the structural
serving win: bits held per weight (= HBM residency / weight-stream bytes
on the target) and the packed-leaf count.

Also replays a deterministic Poisson-ish arrival schedule through BOTH
serving disciplines on the same packed params: static batching (slot-
capped batches served to completion) vs the continuous-batching scheduler
(submit/step/poll; requests join the running decode as slots free).
Latency/wait are counted in dispatch ticks — every decode iteration and
every admission prefill costs one — so the reported win is scheduling,
not accounting; tokens must match request-for-request.

The same arrival schedule then replays as a MIXED-TIER stream: each
request cycles through the artifact's quality tiers and is served at its
own tier inside the one shared decode dispatch (per-request quality),
with every request's tokens verified against a solo single-tier engine.

Emits one BENCH json line for the engine comparison, one for the
continuous-vs-static stream, one for the mixed-tier stream, and one per
quality tier, plus the standard (name, us_per_call, derived) rows for
benchmarks.run.
"""
from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import timeit_us
from repro import api
from repro.configs.base import ArchConfig
from repro.models.api import Model
from repro.models.base import init_params
from repro.optim import AdamWConfig
from repro.quant import tree_bits_report
from repro.quant.artifact import QualitySpec, QualityTier
from repro.serve import (
    QualityShed,
    ServeConfig,
    ServeEngine,
    SLOBudget,
    SpecConfig,
    faults,
)
from repro.train.state import train_state_descs
from repro.train.step import make_cache_prefill_step, make_train_step

PROMPTS = [[1, 2, 3], [9, 9], [100, 42, 7, 8]]
MAX_NEW = 16
PREFILL_LEN = 16  # acceptance: one-dispatch beats scan at prompt len >= 16

# continuous-vs-static arrival schedule (deterministic Poisson-ish stream)
STREAM_REQUESTS = 8
STREAM_MAX_NEW = 8
STREAM_MEAN_GAP = 2.0  # mean inter-arrival, in scheduler ticks
STREAM_SLOTS = 2       # scarce slots: queueing pressure is the point

# demand-driven plane streaming: a tier ladder whose lowest tier keeps ONE
# of the three bit-planes on EVERY packable weight, so an all-lo batch
# should stream ~1/3 of the full-quality weight bytes (the DEFAULT_TIERS
# lo drops one plane from all leaves — a 2/3 floor — which would hide the
# streaming headroom this sweep exists to measure)
PLANE_STREAM_TIERS = QualitySpec((
    QualityTier("hi", drop_planes=0, drop_frac=0.0),
    QualityTier("mid", drop_planes=1, drop_frac=1.0),
    QualityTier("lo", drop_planes=2, drop_frac=1.0),
))
PS_REQUESTS = 6
PS_MAX_NEW = 6
PS_SLOTS = 3

# overload replay: fault-injected arrival floods through two admission
# disciplines on the plane-stream ladder.  Latency/SLO are denominated in
# the engine's COST CLOCK (each dispatch advances time by its weight-read
# fraction: hi=1, mid=2/3, lo=1/3 on PLANE_STREAM_TIERS), so a tier
# downgrade is a real latency lever.  Base gap is tuned so 1x sits inside
# all-hi capacity and 4x is beyond even all-lo capacity.
OV_REQUESTS = 20
OV_MAX_NEW = 8
OV_SLOTS = 4
OV_MEAN_GAP = 3.4          # 1x mean inter-arrival, cost-clock units
OV_FACTORS = (1, 2, 4)     # overload_trace compression factors
OV_SLO = 12.0              # p90 latency budget, cost-clock units
OV_HEADROOM = 0.8          # admission budget = headroom * SLO
OV_DEADLINE = 3 * OV_SLO   # hard deadline -> TIMED_OUT past this

# self-speculative decoding: draft at a cheap tier of the SAME packed
# weights, verify the window in one hi-tier dispatch.  Measured on
# DEFAULT_TIERS (lo = drop one LSB plane everywhere -> reads 2/3), so
# bytes/accepted-token beats plain hi exactly when the per-round
# acceptance rate clears that 2/3 read fraction.  Constant prompts keep
# the trained repeat task in-distribution.
SPEC_PROMPT_SPECS = ((7, 5), (33, 3), (120, 7), (201, 4))
SPEC_MAX_NEW = 12
SPEC_SLOTS = 2
SPEC_TRAIN_STEPS = 600
SPEC_CONFIGS = (("lo", 2), ("lo", 4), ("mid", 2), ("mid", 4))
SPEC_HEADLINE = "lo_k4"


def _model():
    cfg = ArchConfig(name="smollm-bench", family="dense", n_layers=2,
                     d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256,
                     dtype=jnp.float32, remat=False)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_descs())
    return model, params


def _spec_model():
    """The bench model TRAINED on a constant-repeat task (next = current).

    Random weights give near-flat logits, so truncating one LSB plane
    flips the argmax and speculative acceptance collapses to ~0 — hiding
    the byte win this sweep exists to measure.  The repeat task survives
    both 3-bit quantization and single-plane truncation, so draft tiers
    genuinely track the hi tier and acceptance reflects the mechanism,
    not noise.  Fully deterministic: fixed data rng and init key.
    """
    cfg = ArchConfig(name="smollm-bench", family="dense", n_layers=2,
                     d_model=64, n_heads=4, n_kv=2, d_ff=128, vocab=256,
                     dtype=jnp.float32, remat=False)
    model = Model(cfg)
    state = init_params(jax.random.PRNGKey(0), train_state_descs(model))
    step = jax.jit(make_train_step(model, AdamWConfig(lr=3e-3),
                                   total_steps=SPEC_TRAIN_STEPS))
    rng = np.random.default_rng(0)
    for _ in range(SPEC_TRAIN_STEPS):
        first = rng.integers(0, cfg.vocab, size=(8, 1))
        toks = jnp.asarray(np.repeat(first, 16, axis=1), jnp.int32)
        state, _ = step(state, {"tokens": toks, "labels": toks})
    return model, state.params


def _tok_per_s(engine) -> tuple[float, float]:
    """(tokens/s, us/token) for a generate() call, after one warmup."""
    engine.generate(PROMPTS, max_new=MAX_NEW)  # warmup: jit both scans
    n = len(PROMPTS) * MAX_NEW
    t0 = time.time()
    engine.generate(PROMPTS, max_new=MAX_NEW)
    dt = time.time() - t0
    return n / dt, dt / n * 1e6


def _measure(name, eng, params, rows, stats, verbose):
    tok_s, us_tok = _tok_per_s(eng)
    rep = tree_bits_report(eng.params)
    n_w = sum(int(jnp.size(a)) for a in jax.tree_util.tree_leaves(params))
    bits_per_weight = rep["bits"] / n_w
    rows.append((f"serve/{name}", us_tok,
                 f"tok_s={tok_s:.1f}|bits_per_weight={bits_per_weight:.2f}"
                 f"|packed_leaves={eng.n_packed_leaves}"))
    stats[name] = {
        "tok_s": round(tok_s, 2),
        "us_per_tok": round(us_tok, 1),
        "weight_bits": rep["bits"],
        "bits_per_weight": round(bits_per_weight, 2),
        "packed_leaves": eng.n_packed_leaves,
    }
    if verbose:
        print(f"  {name}: {tok_s:.1f} tok/s ({us_tok:.0f} us/tok), "
              f"{bits_per_weight:.2f} bits/weight, "
              f"{eng.n_packed_leaves} packed leaves")
    return stats[name]


def _prefill_compare(model, params, plen: int = PREFILL_LEN, slots: int = 4):
    """(fused_us, scan_us) per prompt batch at prompt length ``plen``.

    Fused = the engine's ONE-DISPATCH full-sequence prefill (packed weights
    stream once per prompt).  Scan = the legacy per-token lax.scan over
    decode steps (weights stream once per TOKEN) — kept here only as the
    baseline the tentpole replaced."""
    cache = init_params(jax.random.PRNGKey(0), model.cache_descs(slots, plen + 2))
    toks = jnp.asarray(
        np.random.default_rng(0).integers(1, model.cfg.vocab, (slots, plen)),
        jnp.int32,
    )
    lens = jnp.full((slots,), plen, jnp.int32)

    fused = jax.jit(make_cache_prefill_step(model), static_argnums=(5,))

    def scan_prefill(params, cache, tokens):
        def body(cache, tok):
            logits, cache = model.decode(params, cache, {"tokens": tok})
            return cache, logits[:, -1, :]

        cache, logits = jax.lax.scan(
            body, cache, jnp.moveaxis(tokens, 1, 0)[:, :, None]
        )
        return cache, logits[-1]

    scan = jax.jit(scan_prefill)
    fused_us = timeit_us(fused, params, cache, toks, lens, warmup=1, iters=5)
    scan_us = timeit_us(scan, params, cache, toks, warmup=1, iters=5)
    return fused_us, scan_us


def _stream_workload(vocab: int, n: int = STREAM_REQUESTS, seed: int = 0):
    """(prompts, arrival ticks): exponential inter-arrival times rounded to
    integer scheduler ticks — a deterministic Poisson-ish request stream."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(scale=STREAM_MEAN_GAP, size=n)
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    arrivals[0] = 0
    prompts = [rng.integers(1, vocab, size=int(rng.integers(2, 6))).tolist()
               for _ in range(n)]
    return prompts, arrivals.tolist()


def _lat_stats(lat, wait):
    return {
        "mean_latency": round(float(np.mean(lat)), 2),
        "p90_latency": round(float(np.percentile(lat, 90)), 2),
        "mean_wait": round(float(np.mean(wait)), 2),
    }


def _run_static_stream(engine, prompts, arrivals, max_new, slots):
    """Static batching under the arrival schedule: the engine takes up to
    ``slots`` already-arrived requests, serves the batch to completion
    (1 prefill tick + max_new decode ticks), and only then admits more —
    late arrivals wait out the whole running batch.  Returns per-request
    (latency, wait) in ticks, the token outputs, and the wall time."""
    t0 = time.time()
    tick, i = 0, 0
    lat, wait = [], []
    outs = [None] * len(prompts)
    while i < len(prompts):
        tick = max(tick, arrivals[i])  # idle until the next arrival
        batch = []
        while i < len(prompts) and arrivals[i] <= tick and len(batch) < slots:
            batch.append(i)
            i += 1
        res = engine.generate([prompts[j] for j in batch], max_new=max_new)
        start = tick
        tick += 1 + max_new  # one prefill dispatch + max_new decode steps
        for j, toks in zip(batch, res, strict=True):
            outs[j] = toks
            wait.append(start - arrivals[j])
            lat.append(tick - arrivals[j])
    return lat, wait, outs, tick, time.time() - t0


def _run_continuous_stream(engine, prompts, arrivals, max_new, tiers=None):
    """The same schedule through submit()/step()/poll(): requests join the
    running decode as slots free.  The tick clock charges every decode
    dispatch 1 and every admission prefill 1 (the same dispatch the static
    path pays once per batch), so the comparison is dispatch-honest.
    ``tiers`` (one quality name per request) submits a MIXED-TIER stream —
    per-request quality inside the shared decode dispatch."""
    t0 = time.time()
    engine.reset_stream()
    tick, i = 0, 0
    arrival_of, index_of, wait_of = {}, {}, {}
    admitted_seen = set()
    lat, wait = [], []
    outs = [None] * len(prompts)
    while i < len(prompts) or engine.has_work:
        if i < len(prompts) and not engine.has_work:
            tick = max(tick, arrivals[i])  # idle until the next arrival
        while i < len(prompts) and arrivals[i] <= tick:
            rid = engine.submit(prompts[i], max_new=max_new,
                                quality=None if tiers is None else tiers[i])
            arrival_of[rid], index_of[rid] = arrivals[i], i
            i += 1
        engine.step()
        admitted = engine.live_requests + list(
            engine.completed_requests.values())
        new_admits = [r for r in admitted
                      if r.admitted is not None and r.rid not in admitted_seen]
        for r in new_admits:
            admitted_seen.add(r.rid)
            wait_of[r.rid] = tick - arrival_of[r.rid]
        tick += 1 + len(new_admits)
        for rid, st in engine.poll().items():
            outs[index_of[rid]] = st.tokens
            lat.append(tick - arrival_of[rid])
            wait.append(wait_of[rid])
    return lat, wait, outs, tick, time.time() - t0


def main(verbose: bool = True, quick: bool = False):
    del quick  # the serve bench is already its own smallest configuration
    model, params = _model()
    artifact = api.compress(model, params)

    # static scan-path engines: isolates the weight-format comparison from
    # scheduler dispatch overhead (the continuous stream is measured below)
    engines = {
        "dense_exact": ServeEngine(model, params,
                                   ServeConfig(batch_slots=4,
                                               continuous=False)),
        "wire_dense": artifact.engine(quality="hi", batch_slots=4,
                                      packed=False, continuous=False),
        "wire_packed": artifact.engine(quality="hi", batch_slots=4,
                                       continuous=False),
    }

    rows = []
    stats = {}
    for name, eng in engines.items():
        _measure(name, eng, params, rows, stats, verbose)

    # tokens must agree bit-exactly across the two wire engines
    outs = [eng.generate(PROMPTS, max_new=8) for eng in
            (engines["wire_dense"], engines["wire_packed"])]
    assert outs[0] == outs[1], "packed engine diverged from dense decode"

    # per-prompt prefill cost on the packed tree: the one-dispatch prefill
    # streams every packed weight once per prompt; the scan streamed them
    # once per token.
    fused_us, scan_us = _prefill_compare(model, engines["wire_packed"].params)
    rows.append(("serve/prefill_one_dispatch", fused_us,
                 f"scan_us={scan_us:.0f}|len={PREFILL_LEN}"
                 f"|speedup={scan_us / max(fused_us, 1e-9):.2f}x"))
    if verbose:
        print(f"  prefill(len={PREFILL_LEN}): one-dispatch {fused_us:.0f}us "
              f"vs scan {scan_us:.0f}us "
              f"({scan_us / max(fused_us, 1e-9):.2f}x)")

    print("BENCH " + json.dumps({"bench": "serve",
                                 "prompts": len(PROMPTS),
                                 "max_new": MAX_NEW,
                                 "prefill_len": PREFILL_LEN,
                                 "prefill_us": round(fused_us, 1),
                                 "scan_prefill_us": round(scan_us, 1),
                                 **stats}))

    # continuous vs static batching under a Poisson-ish arrival schedule:
    # same packed params, same stream; the static engine serves
    # slot-capped batches to completion while the scheduler admits each
    # request into the first freed slot.  The tick clock charges every
    # dispatch (admission prefills included), so lower continuous latency
    # is a scheduling win, not an accounting artifact.
    prompts, arrivals = _stream_workload(model.cfg.vocab)
    eng_cont = ServeEngine(model, engines["wire_packed"].params, ServeConfig(
        batch_slots=STREAM_SLOTS, max_prompt=8,
        max_len=8 + STREAM_MAX_NEW + 1,
    ))
    eng_stat = ServeEngine(model, engines["wire_packed"].params, ServeConfig(
        batch_slots=STREAM_SLOTS, continuous=False,
    ))
    # first replay warms every program (batch-shape retraces included), the
    # second is the measured one — tick metrics are identical across both
    _run_static_stream(eng_stat, prompts, arrivals, STREAM_MAX_NEW,
                       STREAM_SLOTS)
    _run_continuous_stream(eng_cont, prompts, arrivals, STREAM_MAX_NEW)
    s_lat, s_wait, s_outs, s_ticks, s_wall = _run_static_stream(
        eng_stat, prompts, arrivals, STREAM_MAX_NEW, STREAM_SLOTS)
    c_lat, c_wait, c_outs, c_ticks, c_wall = _run_continuous_stream(
        eng_cont, prompts, arrivals, STREAM_MAX_NEW)
    assert c_outs == s_outs, \
        "continuous stream diverged from static batching tokens"
    assert float(np.mean(c_lat)) <= float(np.mean(s_lat)), \
        f"continuous mean latency {np.mean(c_lat)} worse than static {np.mean(s_lat)}"
    n_tok = len(prompts) * STREAM_MAX_NEW
    stream_stats = {
        "static": {**_lat_stats(s_lat, s_wait), "ticks": s_ticks,
                   "tok_per_tick": round(n_tok / s_ticks, 3),
                   "tok_s_wall": round(n_tok / s_wall, 1)},
        "continuous": {**_lat_stats(c_lat, c_wait), "ticks": c_ticks,
                       "tok_per_tick": round(n_tok / c_ticks, 3),
                       "tok_s_wall": round(n_tok / c_wall, 1)},
    }
    ratio = np.mean(s_lat) / max(np.mean(c_lat), 1e-9)
    rows.append(("serve/continuous_stream", c_wall / n_tok * 1e6,
                 f"mean_latency={np.mean(c_lat):.1f}t"
                 f"|static={np.mean(s_lat):.1f}t|x{ratio:.2f}"))
    if verbose:
        print(f"  stream({len(prompts)} reqs, {STREAM_SLOTS} slots): "
              f"continuous mean latency {np.mean(c_lat):.1f} ticks vs "
              f"static {np.mean(s_lat):.1f} ({ratio:.2f}x), tokens exact")
    print("BENCH " + json.dumps({
        "bench": "serve_continuous",
        "requests": len(prompts),
        "slots": STREAM_SLOTS,
        "max_new": STREAM_MAX_NEW,
        "mean_gap": STREAM_MEAN_GAP,
        "tokens_match": c_outs == s_outs,
        "latency_ratio": round(float(ratio), 2),
        **stream_stats,
    }))

    # MIXED-TIER continuous stream: the same Poisson-ish arrival schedule,
    # each request cycled through the artifact's tiers (hi/mid/lo...) and
    # served at ITS tier inside the one shared decode dispatch (per-row
    # plane masks — no retrace, no param swap).  Every request's tokens
    # must match a single-tier engine serving it alone at that tier.
    tier_names = artifact.quality_names()
    mix = [tier_names[i % len(tier_names)] for i in range(len(prompts))]
    eng_mix = artifact.engine(quality="hi", batch_slots=STREAM_SLOTS,
                              max_prompt=8, max_len=8 + STREAM_MAX_NEW + 1)
    assert eng_mix.per_request_quality
    _run_continuous_stream(eng_mix, prompts, arrivals, STREAM_MAX_NEW,
                           tiers=mix)  # warm every program
    m_lat, m_wait, m_outs, m_ticks, m_wall = _run_continuous_stream(
        eng_mix, prompts, arrivals, STREAM_MAX_NEW, tiers=mix)
    solo = {}
    for q in tier_names:
        solo[q] = artifact.engine(quality=q, per_request=False,
                                  batch_slots=1, continuous=False)
    mix_exact = all(
        m_outs[i] == solo[mix[i]].generate([prompts[i]],
                                           max_new=STREAM_MAX_NEW)[0]
        for i in range(len(prompts))
    )
    assert mix_exact, "mixed-tier stream diverged from solo-tier engines"
    rows.append(("serve/mixed_tier_stream", m_wall / n_tok * 1e6,
                 f"mean_latency={np.mean(m_lat):.1f}t"
                 f"|tok_per_tick={n_tok / m_ticks:.3f}|tiers={len(tier_names)}"))
    if verbose:
        print(f"  mixed-tier stream ({'/'.join(tier_names)}): "
              f"mean latency {np.mean(m_lat):.1f} ticks, "
              f"{n_tok / m_ticks:.3f} tok/tick, per-request tokens exact")
    print("BENCH " + json.dumps({
        "bench": "serve_mixed_tier",
        "requests": len(prompts),
        "slots": STREAM_SLOTS,
        "max_new": STREAM_MAX_NEW,
        "tier_mix": {q: mix.count(q) for q in tier_names},
        "tokens_match_solo_tier": mix_exact,
        "tok_per_tick": round(n_tok / m_ticks, 3),
        **_lat_stats(m_lat, m_wait),
    }))

    # DEMAND-DRIVEN PLANE STREAMING: the same continuous scheduler, swept
    # over tier mixes on a ladder whose lo tier keeps one plane everywhere.
    # Each decode tick streams only the planes the batch's most-demanding
    # LIVE request wants (min live tier index, a static dispatch arg), and
    # the engine's analytic meter converts that into weight bytes read per
    # token — an all-lo batch should approach 1/3 of the all-hi traffic.
    # Outputs stay bit-exact vs solo single-tier engines at every mix.
    ps_art = api.compress(model, params, tiers=PLANE_STREAM_TIERS)
    ps_rng = np.random.default_rng(7)
    ps_prompts = [ps_rng.integers(1, model.cfg.vocab,
                                  size=int(ps_rng.integers(2, 6))).tolist()
                  for _ in range(PS_REQUESTS)]
    ps_names = ps_art.quality_names()
    ps_solo = {q: ps_art.engine(quality=q, per_request=False, batch_slots=1,
                                continuous=False) for q in ps_names}
    eng_ps = ps_art.engine(quality="hi", batch_slots=PS_SLOTS, max_prompt=8,
                           max_len=8 + PS_MAX_NEW + 1)
    assert eng_ps.per_request_quality
    mixes = {
        "all_hi": ["hi"] * PS_REQUESTS,
        "mixed": [ps_names[i % len(ps_names)] for i in range(PS_REQUESTS)],
        "all_lo": ["lo"] * PS_REQUESTS,
    }
    ps_stats = {}
    for mix_name, mix_tiers in mixes.items():
        eng_ps.reset_stream()  # fresh session: per-mix traffic meter
        rids = [eng_ps.submit(p, max_new=PS_MAX_NEW, quality=q)
                for p, q in zip(ps_prompts, mix_tiers, strict=True)]
        done = eng_ps.run_until_drained()
        for rid, p, q in zip(rids, ps_prompts, mix_tiers, strict=True):
            assert done[rid].tokens == ps_solo[q].generate(
                [p], max_new=PS_MAX_NEW)[0], \
                f"plane-stream {mix_name} diverged from solo {q} engine"
        meter = eng_ps.stream_stats()
        ps_stats[mix_name] = {
            "bytes_per_token": round(meter["bytes_per_token"], 1),
            "read_frac": round(meter["read_frac"], 4),
            "tok_per_tick": round(meter["tokens"] / eng_ps.step_count, 3),
            "tokens": meter["tokens"],
        }
        if verbose:
            print(f"  plane_stream/{mix_name}: "
                  f"{meter['bytes_per_token']:.0f} B/tok "
                  f"({meter['read_frac']:.2f} of full), "
                  f"{ps_stats[mix_name]['tok_per_tick']:.3f} tok/tick, "
                  f"tokens exact")
    hi_bpt = ps_stats["all_hi"]["bytes_per_token"]
    lo_bpt = ps_stats["all_lo"]["bytes_per_token"]
    assert lo_bpt < hi_bpt, \
        f"all-lo bytes/token {lo_bpt} not below all-hi {hi_bpt}"
    assert lo_bpt <= 0.5 * hi_bpt, \
        f"all-lo bytes/token {lo_bpt} > 0.5x all-hi {hi_bpt}"
    rows.append(("serve/plane_stream_all_lo", lo_bpt,
                 f"all_hi_B_tok={hi_bpt:.0f}"
                 f"|ratio={lo_bpt / hi_bpt:.3f}"))
    print("BENCH " + json.dumps({
        "bench": "serve_plane_stream",
        "requests": PS_REQUESTS,
        "slots": PS_SLOTS,
        "max_new": PS_MAX_NEW,
        "lo_over_hi_bytes": round(lo_bpt / hi_bpt, 4),
        **ps_stats,
    }))

    # OVERLOAD REPLAY: identical fault-injected arrival floods through two
    # admission disciplines on the same plane-stream artifact.  The FIFO
    # baseline admits everything at the requested (hi) tier; QualityShed
    # downgrades hi->mid->lo against an SLO budget and sheds only when
    # even lo misses it.  Both run the one continuous decode dispatch —
    # admissions, evictions and deadline timeouts are active-mask flips,
    # never retraces — and every dropped request carries a typed
    # finish_reason instead of a hang.  The gate: at 4x overload the
    # shedding engine holds p90 latency under the SLO where FIFO blows it,
    # with bounded queue depth.
    ov_rng = np.random.default_rng(11)
    ov_prompts = [ov_rng.integers(1, model.cfg.vocab,
                                  size=int(ov_rng.integers(2, 6))).tolist()
                  for _ in range(OV_REQUESTS)]
    ov_base = faults.poisson_trace(OV_REQUESTS, OV_MEAN_GAP, seed=3)
    policy = QualityShed(SLOBudget(latency=OV_HEADROOM * OV_SLO,
                                   max_queue=2 * OV_SLOTS))
    ov_engines = {
        "fifo": ps_art.engine(quality="hi", batch_slots=OV_SLOTS,
                              max_prompt=8, max_len=8 + OV_MAX_NEW + 1),
        "shed": ps_art.engine(quality="hi", batch_slots=OV_SLOTS,
                              max_prompt=8, max_len=8 + OV_MAX_NEW + 1,
                              admission=policy),
    }
    ov_stats = {}
    for disc, eng in ov_engines.items():
        assert eng.per_request_quality
        per_factor = {}
        for factor in OV_FACTORS:
            eng.reset_stream()
            trace = faults.overload_trace(ov_base, factor)
            report = faults.replay(eng, ov_prompts, trace,
                                   max_new=OV_MAX_NEW, qualities="hi",
                                   deadline=OV_DEADLINE)
            per_factor[f"{factor}x"] = report.summary()
            if verbose:
                s = per_factor[f"{factor}x"]
                print(f"  overload/{disc}@{factor}x: "
                      f"p90={s['p90_latency']} "
                      f"shed={s['shed_rate']} timeout={s['timeout_rate']} "
                      f"depth={s['max_queue_depth']} mix={s['quality_mix']}")
        ov_stats[disc] = per_factor
    shed4 = ov_stats["shed"]["4x"]
    fifo4 = ov_stats["fifo"]["4x"]
    for factor in OV_FACTORS:
        s = ov_stats["shed"][f"{factor}x"]
        assert s["p90_latency"] <= OV_SLO, \
            f"shed p90 {s['p90_latency']} blows SLO {OV_SLO} at {factor}x"
    assert fifo4["p90_latency"] > OV_SLO, \
        f"FIFO p90 {fifo4['p90_latency']} met SLO at 4x — raise overload"
    assert shed4["max_queue_depth"] <= 2 * OV_SLOTS, \
        f"shed queue depth {shed4['max_queue_depth']} unbounded at 4x"
    assert shed4["shed_rate"] + shed4["reject_rate"] > 0, \
        "4x overload never exercised shedding"
    rows.append(("serve/overload_shed_p90_4x", shed4["p90_latency"],
                 f"fifo_p90={fifo4['p90_latency']}|slo={OV_SLO}"
                 f"|shed_rate={shed4['shed_rate']}"))
    print("BENCH " + json.dumps({
        "bench": "serve_overload",
        "requests": OV_REQUESTS,
        "slots": OV_SLOTS,
        "max_new": OV_MAX_NEW,
        "slo": OV_SLO,
        "deadline": OV_DEADLINE,
        "budget": OV_HEADROOM * OV_SLO,
        "slo_met_shed_4x": shed4["p90_latency"] <= OV_SLO,
        "slo_met_fifo_4x": fifo4["p90_latency"] <= OV_SLO,
        **ov_stats,
    }))

    # SELF-SPECULATIVE DECODING: the quality dial IS the draft model.
    # Each speculating slot drafts k tokens at a cheap tier (the demand
    # floor streams only that tier's planes), then ONE hi-tier dispatch
    # verifies the whole window; the longest agreeing prefix is kept and
    # rejected tokens are a per-slot KV pos rollback, never a retrace.
    # Outputs must be token-identical to plain hi decode; the win is
    # weight bytes per ACCEPTED token, which beats plain hi exactly when
    # acceptance clears the draft tier's read fraction (2/3 for lo on
    # DEFAULT_TIERS).  Swept over draft tier x window size on the
    # trained model, where the repeat task makes acceptance real.
    sp_model, sp_params = _spec_model()
    sp_art = api.compress(sp_model, sp_params, tiers=api.DEFAULT_TIERS)
    sp_prompts = [[t] * n for t, n in SPEC_PROMPT_SPECS]
    sp_plain = sp_art.engine(quality="hi", batch_slots=SPEC_SLOTS,
                             max_prompt=8, max_len=8 + SPEC_MAX_NEW + 1)
    sp_rids = [sp_plain.submit(p, max_new=SPEC_MAX_NEW) for p in sp_prompts]
    sp_done = sp_plain.run_until_drained()
    sp_oracle = [sp_done[r].tokens for r in sp_rids]
    sp_hi_bpt = sp_plain.stream_stats()["bytes_per_token"]
    sp_stats: dict = {}
    sp_exact = True
    for draft, k in SPEC_CONFIGS:
        eng_sp = sp_art.engine(quality="hi", batch_slots=SPEC_SLOTS,
                               max_prompt=8, max_len=8 + SPEC_MAX_NEW + 1)
        sp_r = [eng_sp.submit(p, max_new=SPEC_MAX_NEW,
                              speculate=SpecConfig(draft, k))
                for p in sp_prompts]
        sp_d = eng_sp.run_until_drained()
        sp_exact &= all(sp_d[r].tokens == t
                        for r, t in zip(sp_r, sp_oracle, strict=True))
        st = eng_sp.stream_stats()
        sp_stats[f"{draft}_k{k}"] = {
            "acceptance_rate": round(st["acceptance_rate"], 4),
            "bytes_per_token": round(st["bytes_per_token"], 1),
            "drafted": st["drafted"],
            "accepted": st["accepted"],
            "tokens": st["tokens"],
        }
        if verbose:
            print(f"  speculative/{draft}_k{k}: "
                  f"acc={st['acceptance_rate']:.3f} "
                  f"{st['bytes_per_token']:.0f} B/tok "
                  f"(hi {sp_hi_bpt:.0f}), tokens exact")
    assert sp_exact, "speculative decode diverged from plain hi tokens"
    sp_head = sp_stats[SPEC_HEADLINE]
    assert sp_head["bytes_per_token"] < sp_hi_bpt, \
        (f"speculative {SPEC_HEADLINE} bytes/token "
         f"{sp_head['bytes_per_token']} not below plain hi {sp_hi_bpt}")
    rows.append((f"serve/speculative_{SPEC_HEADLINE}",
                 sp_head["bytes_per_token"],
                 f"hi_B_tok={sp_hi_bpt:.0f}"
                 f"|acc={sp_head['acceptance_rate']:.3f}"
                 f"|ratio={sp_head['bytes_per_token'] / sp_hi_bpt:.3f}"))
    print("BENCH " + json.dumps({
        "bench": "serve_speculative",
        "requests": len(sp_prompts),
        "slots": SPEC_SLOTS,
        "max_new": SPEC_MAX_NEW,
        "train_steps": SPEC_TRAIN_STEPS,
        "hi_bytes_per_token": round(sp_hi_bpt, 1),
        "headline": SPEC_HEADLINE,
        "tokens_exact": sp_exact,
        **sp_stats,
    }))

    # quality-tier sweep: one engine per tier from the SAME artifact, lower
    # tiers realized by LSB plane truncation (never a re-quantize); one
    # BENCH line per tier so the perf trajectory captures the
    # quality/throughput trade-off.  'hi' IS the wire_packed engine — reuse
    # it instead of repacking and re-jitting an identical tree.
    for tier in artifact.quality_names():
        drop = artifact.drop_map(tier)
        eng = (engines["wire_packed"] if not drop
               else artifact.engine(quality=tier, batch_slots=4,
                                    continuous=False))
        tier_stats = _measure(f"tier_{tier}", eng, params, rows, stats,
                              verbose)
        print("BENCH " + json.dumps({
            "bench": "serve_quality",
            "tier": tier,
            "truncated_leaves": len(drop),
            "tok_s": tier_stats["tok_s"],
            "weight_bits": tier_stats["weight_bits"],
            "packed_leaves": tier_stats["packed_leaves"],
        }))

    return rows


if __name__ == "__main__":
    main()

"""CI acceptance gates over BENCH lines — checked in, unit-testable.

The bench-smoke job runs ``benchmarks.run --quick --bench-out
bench-lines.jsonl`` and then invokes this module once per gate::

    python -m benchmarks.gates plane-stream --bench-lines bench-lines.jsonl
    python -m benchmarks.gates overload     --bench-lines bench-lines.jsonl
    python -m benchmarks.gates speculative  --bench-lines bench-lines.jsonl

Each gate extracts its BENCH records, writes them to a
``BENCH_<name>.jsonl`` artifact (so the trajectory survives the run even
when the gate fails), and enforces the acceptance bar — strictly-better
structural properties plus a seeded baseline from
``benchmarks/baselines/`` where one exists.  Gate logic lives in plain
functions over parsed records (no file I/O), so the failure modes are
unit-tested in ``tests/test_gates.py`` instead of living as untestable
heredocs inside the workflow YAML.

Exit codes: 0 gate passed, 1 gate failed (message on stderr), 2 usage
error (argparse).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

BASELINE_DIR = Path(__file__).resolve().parent / "baselines"


class GateError(Exception):
    """A gate's acceptance bar was not met (or its input is missing)."""


def parse_bench_lines(lines) -> list[dict]:
    """Parse an iterable of jsonl/BENCH-prefixed lines into records."""
    out = []
    for line in lines:
        line = line.strip()
        if not line:
            continue
        if line.startswith("BENCH "):
            line = line[len("BENCH "):]
        out.append(json.loads(line))
    return out


def extract(records: list[dict], bench: str) -> list[dict]:
    """The records for one bench; raises if the bench never emitted."""
    hits = [d for d in records if d.get("bench") == bench]
    if not hits:
        raise GateError(f"no {bench} BENCH line emitted")
    return hits


def gate_plane_stream(records: list[dict], baseline: dict) -> str:
    """Demand-driven streaming must actually shorten HBM reads: the
    all-lo mix reads strictly fewer weight bytes per token than all-hi,
    and no more than the seeded baseline ratio allows."""
    ps = extract(records, "serve_plane_stream")
    for d in ps:
        lo = d["all_lo"]["bytes_per_token"]
        hi = d["all_hi"]["bytes_per_token"]
        if not lo < hi:
            raise GateError(
                f"all-lo bytes/token {lo} not strictly below all-hi {hi}")
        if lo / hi > baseline["lo_over_hi_bytes"] + 1e-6:
            raise GateError(
                f"lo/hi byte ratio {lo / hi:.4f} regressed past "
                f"baseline {baseline['lo_over_hi_bytes']}")
    return ("plane-stream traffic gate ok: "
            f"{[round(d['lo_over_hi_bytes'], 4) for d in ps]}")


def gate_overload(records: list[dict]) -> str:
    """Overload-graceful serving must actually hold the SLO at 4x: shed
    p90 under the budget where FIFO blows it, bounded queue, and a
    nonzero shed/reject rate (the overload was real)."""
    ov = extract(records, "serve_overload")
    for d in ov:
        slo = d["slo"]
        shed4, fifo4 = d["shed"]["4x"], d["fifo"]["4x"]
        if shed4["p90_latency"] > slo:
            raise GateError(f"shed p90 {shed4['p90_latency']} blows the "
                            f"SLO {slo} at 4x overload")
        if fifo4["p90_latency"] <= slo:
            raise GateError(f"FIFO baseline p90 {fifo4['p90_latency']} met "
                            f"the SLO at 4x — the overload gate is vacuous")
        if shed4["max_queue_depth"] > 2 * d["slots"]:
            raise GateError(f"shed queue depth {shed4['max_queue_depth']} "
                            f"exceeds the 2x-slots bound at 4x")
        if shed4["shed_rate"] + shed4["reject_rate"] <= 0:
            raise GateError("4x overload never exercised shedding")
    return ("overload shedding gate ok: "
            f"{[(d['shed']['4x']['p90_latency'], d['fifo']['4x']['p90_latency']) for d in ov]}")


def gate_speculative(records: list[dict], baseline: dict) -> str:
    """Self-speculative decoding must stay exact AND pay for itself:
    verified tokens identical to plain hi decode, headline acceptance
    rate at or above the seeded floor, and weight bytes per accepted
    token strictly below plain hi — by at least the baseline margin."""
    sp = extract(records, "serve_speculative")
    for d in sp:
        if not d.get("tokens_exact", False):
            raise GateError("speculative tokens diverged from plain hi "
                            "decode — exactness is the contract")
        head = d[d["headline"]]
        acc = head["acceptance_rate"]
        if acc < baseline["min_acceptance_rate"]:
            raise GateError(
                f"headline {d['headline']} acceptance rate {acc:.4f} below "
                f"seeded floor {baseline['min_acceptance_rate']}")
        hi = d["hi_bytes_per_token"]
        ratio = head["bytes_per_token"] / hi
        if not head["bytes_per_token"] < hi:
            raise GateError(
                f"speculative bytes/accepted-token "
                f"{head['bytes_per_token']} not below plain hi {hi}")
        if ratio > baseline["max_spec_over_hi_bytes"] + 1e-6:
            raise GateError(
                f"spec/hi byte ratio {ratio:.4f} regressed past "
                f"baseline {baseline['max_spec_over_hi_bytes']}")
    heads = [(d[d["headline"]]["acceptance_rate"],
              round(d[d["headline"]]["bytes_per_token"]
                    / d["hi_bytes_per_token"], 4)) for d in sp]
    return f"speculative decode gate ok: {heads}"


def load_baseline(name: str, baseline_dir: Path = BASELINE_DIR) -> dict:
    path = baseline_dir / f"{name}.json"
    try:
        return json.loads(path.read_text())
    except FileNotFoundError:
        raise GateError(f"missing seeded baseline {path}") from None


def write_artifact(records: list[dict], path: Path) -> None:
    with open(path, "w") as f:
        for d in records:
            f.write(json.dumps(d) + "\n")


GATES = {
    "plane-stream": ("serve_plane_stream", gate_plane_stream, True),
    "overload": ("serve_overload", gate_overload, False),
    "speculative": ("serve_speculative", gate_speculative, True),
}


def run_gate(gate: str, records: list[dict], *,
             baseline_dir: Path = BASELINE_DIR,
             artifact_dir: Path | None = None) -> str:
    """Extract + artifact + enforce one named gate; returns the ok line."""
    bench, fn, needs_baseline = GATES[gate]
    # the artifact is written BEFORE enforcement so a failing gate still
    # uploads the measured lines for debugging
    try:
        hits = [d for d in records if d.get("bench") == bench]
        if artifact_dir is not None and hits:
            write_artifact(hits, artifact_dir / f"BENCH_{bench}.jsonl")
        if needs_baseline:
            return fn(records, load_baseline(f"BENCH_{bench}",
                                             baseline_dir))
        return fn(records)
    except KeyError as e:
        raise GateError(f"BENCH line missing expected key: {e}") from None


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="benchmarks.gates",
        description="CI acceptance gates over BENCH jsonl lines")
    ap.add_argument("gate", choices=sorted(GATES))
    ap.add_argument("--bench-lines", default="bench-lines.jsonl",
                    help="path to the jsonl of BENCH lines from "
                         "benchmarks.run --bench-out")
    ap.add_argument("--baselines-dir", type=Path, default=BASELINE_DIR)
    ap.add_argument("--artifact-dir", type=Path, default=Path("."),
                    help="where BENCH_<bench>.jsonl is written")
    args = ap.parse_args(argv)
    try:
        with open(args.bench_lines) as f:
            records = parse_bench_lines(f)
        msg = run_gate(args.gate, records,
                       baseline_dir=args.baselines_dir,
                       artifact_dir=args.artifact_dir)
    except (GateError, OSError, json.JSONDecodeError) as e:
        print(f"GATE FAIL [{args.gate}]: {e}", file=sys.stderr)
        return 1
    print(msg)
    return 0


if __name__ == "__main__":
    sys.exit(main())

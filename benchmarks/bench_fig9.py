"""Fig. 9 (Eq. 11/12): memory savings from encoding vectors of full-precision
weights, as a function of vector length N, for 2-bit and 3-bit encoding.

Paper headline: up to 82.49% parameter reduction on LeNet.
This benchmark is pure arithmetic (the paper's own equations) — exact, not
dataset-dependent — plus the LeNet/ConvNet aggregates.
"""
from __future__ import annotations

import time

from repro.core.energy import memory_savings, model_savings
from repro.models.cnn import CONVNET4, LENET, conv_layer_shapes


def main(verbose: bool = True, vector_lengths=(2, 4, 8, 16, 32, 64)):
    t0 = time.time()
    rows = []
    for be in (2, 3):
        for n in vector_lengths:
            s = memory_savings(2**20, n, be)
            rows.append((f"fig9/be{be}_N{n}", s))
    for name, cfg in (("lenet", LENET), ("convnet4", CONVNET4)):
        rep = model_savings(conv_layer_shapes(cfg), group_size=16, bit_encoding=3)
        rows.append((f"fig9/{name}_conv_savings", rep["memory_savings"]))
    dt = time.time() - t0
    if verbose:
        print("Fig. 9 — memory savings vs vector length (Eq. 11/12):")
        for name, s in rows:
            print(f"  {name:28s} savings={s * 100:.2f}%")
        print("  paper headline: 82.49% (LeNet, all params incl. FC)")
    return [(name, dt / len(rows) * 1e6, f"{s * 100:.2f}%") for name, s in rows]


if __name__ == "__main__":
    main()

"""Tile autotuner for the packed QSQ kernels.

Sweeps candidate (bm, bk, bn) tile configs per benchmark shape, times the
routed kernel (`ops.qsq_matvec` for decode shapes, `ops.qsq_matmul`
otherwise), and writes the winners as a dispatch table
(`kernels/dispatch.py` format): one exact entry per swept shape plus one
"gemv"/"gemm" class default per backend (the config winning the most
shapes of that class).

On a real TPU this measures the Mosaic kernels and the table is worth
checking in (``--apply`` overwrites ``src/repro/kernels/tuned_tiles.json``;
re-run there after any kernel change).  On CPU the kernels execute in
interpret mode, where timing reflects the interpreter, not the target —
the sweep still validates that every candidate config runs and produces
a loadable table, which is what the CI smoke uses (``--quick``).

  PYTHONPATH=src python -m benchmarks.autotune [--quick] [--apply]
      [--out PATH]

Emits one ``BENCH {json}`` line per (shape, config) measurement and a
final summary row per shape.
"""
from __future__ import annotations

import argparse
import itertools
import json
import jax
import jax.numpy as jnp

from benchmarks.common import timeit_us
from repro.core import codec
from repro.kernels import dispatch, ops, ref

# (M, K, N, G) per shape class: decode GEMVs (M = batch slots) and
# prefill/train GEMMs.  Tile-divisible shapes only: the tuner sweeps raw
# kernel tiles; ragged shapes resolve THROUGH these class winners (the
# dispatcher pads them to the fitted tile at plan time).
GEMV_SHAPES = [
    (1, 4096, 4096, 64),
    (8, 2048, 2048, 64),
    (8, 4096, 4096, 64),
]
GEMM_SHAPES = [
    (128, 4096, 4096, 64),
    (256, 2048, 2048, 64),
]
QUICK_SHAPES = [(8, 512, 256, 64), (64, 512, 256, 64)]

# candidate tile sweeps (clamped to the shape by the kernels)
GEMV_CANDS = {
    "bk": (512, 1024, 2048),
    "bn": (128, 256, 512),
}
GEMM_CANDS = {
    "bm": (128, 256),
    "bk": (256, 512),
    "bn": (128, 256, 512),
}


def _inputs(m, k, n, g, seed=0):
    w = jax.random.normal(jax.random.PRNGKey(seed), (k, n)) * 0.05
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (m, k), jnp.bfloat16)
    codes, scales = ref.qsq_quantize_ref(w, g, 4)
    return x, codec.pack_bitplane(codes), scales


def _valid(kind, m, k, n, g, cfg) -> bool:
    """cfg tiles are pre-clamped to the shape by the sweep."""
    if k % cfg["bk"] or cfg["bk"] % codec.PLANE_GROUP or cfg["bk"] % g:
        return False
    if n % cfg["bn"]:
        return False
    if kind == "gemm" and m % cfg["bm"]:
        return False
    return True


def _sweep_one(kind, m, k, n, g, verbose):
    x, planes, scales = _inputs(m, k, n, g)
    cands = GEMV_CANDS if kind == "gemv" else GEMM_CANDS
    names = list(cands)
    dims = {"bm": m, "bk": k, "bn": n}
    best = None
    seen = set()
    for vals in itertools.product(*(cands[nm] for nm in names)):
        # clamp to the shape up front: dedupes candidates that the kernel
        # would clamp to the same tiling, and keeps the stored winner's
        # tiles <= the dimension they tile
        cfg = {nm: min(v, dims[nm]) for nm, v in zip(names, vals, strict=True)}
        if tuple(sorted(cfg.items())) in seen:
            continue
        seen.add(tuple(sorted(cfg.items())))
        if not _valid(kind, m, k, n, g, cfg):
            continue
        if kind == "gemv":
            fn = lambda x, p, s: ops.qsq_matvec(  # noqa: E731
                x, p, s, group_size=g, bk=cfg["bk"], bn=cfg["bn"])
        else:
            fn = lambda x, p, s: ops.qsq_matmul(  # noqa: E731
                x, p, s, group_size=g, bm=cfg["bm"], bk=cfg["bk"],
                bn=cfg["bn"])
        us = timeit_us(fn, x, planes, scales, warmup=1, iters=3)
        print("BENCH " + json.dumps({
            "bench": "autotune", "case": dispatch.shape_key(m, k, n, g),
            "kind": kind, **cfg, "us": round(us, 1),
        }))
        if best is None or us < best[0]:
            best = (us, cfg)
    if best is None:
        if verbose:
            print(f"  {kind} {dispatch.shape_key(m, k, n, g)}: no candidate "
                  f"tile divides this shape — skipping (ragged shapes are "
                  f"padded by the dispatcher, not tuned directly)")
        return None
    us, cfg = best
    full = {"kind": kind, "bm": cfg.get("bm", min(m, dispatch.SUBLANE)),
            "bk": cfg["bk"], "bn": cfg["bn"]}
    if verbose:
        print(f"  {kind} {dispatch.shape_key(m, k, n, g)}: best {full} "
              f"({us:.0f}us)")
    return us, full


def _demand_sweep(m, k, n, g, cfg, verbose) -> list:
    """Time the decode GEMV at each demand_drop on plane-major weights,
    using the tuned tiles.  This does NOT feed the tile table — demand is
    a dispatch-time static, not a tunable — it records how the winning
    config's runtime scales as demand shortens the weight-plane stream
    (~linear in planes on the target, since decode is weight-bound)."""
    x, planes, scales = _inputs(m, k, n, g)
    pm = codec.plane_major(planes)
    rows = []
    for drop in (0, 1, 2):
        fn = lambda x, p, s: ops.qsq_matvec(  # noqa: E731
            x, p, s, group_size=g, bk=cfg["bk"], bn=cfg["bn"],
            plane_major=True, demand_drop=drop)
        us = timeit_us(fn, x, pm, scales, warmup=1, iters=3)
        print("BENCH " + json.dumps({
            "bench": "autotune_demand", "case": dispatch.shape_key(m, k, n, g),
            "demand_drop": drop, "planes_read": 3 - drop,
            "bk": cfg["bk"], "bn": cfg["bn"], "us": round(us, 1),
        }))
        rows.append((f"autotune/demand{drop}_{dispatch.shape_key(m, k, n, g)}",
                     us, f"planes={3 - drop}|bk={cfg['bk']}|bn={cfg['bn']}"))
        if verbose:
            print(f"  demand_drop={drop} ({3 - drop} planes) "
                  f"{dispatch.shape_key(m, k, n, g)}: {us:.0f}us")
    return rows


def tune(quick: bool = False, verbose: bool = True) -> tuple[dict, list]:
    """Run the sweep; returns (dispatch-format table, bench rows)."""
    backend = jax.default_backend()
    if verbose and backend != "tpu":
        print(f"  NOTE: backend={backend} runs Pallas in interpret mode — "
              f"timings rank the interpreter, not the target; re-tune on "
              f"TPU before trusting the table")
    shapes = ([(s, dispatch.shape_class(s[0])) for s in QUICK_SHAPES]
              if quick else
              [(s, "gemv") for s in GEMV_SHAPES]
              + [(s, "gemm") for s in GEMM_SHAPES])
    entries: dict = {}
    rows = []
    class_votes: dict = {"gemv": {}, "gemm": {}}
    for (m, k, n, g), kind in shapes:
        result = _sweep_one(kind, m, k, n, g, verbose)
        if result is None:
            continue
        us, cfg = result
        entries[dispatch.shape_key(m, k, n, g)] = cfg
        rows.append((f"autotune/{dispatch.shape_key(m, k, n, g)}", us,
                     f"kind={kind}|bm={cfg['bm']}|bk={cfg['bk']}|bn={cfg['bn']}"))
        key = json.dumps(cfg, sort_keys=True)
        class_votes[kind][key] = class_votes[kind].get(key, 0) + 1
    for kind, votes in class_votes.items():
        if votes:
            entries[kind] = json.loads(max(votes, key=votes.get))
    # demand-streaming scaling on the first decode shape's winning tiles
    gemv_cfg = entries.get("gemv")
    gemv_shape = next((s for s, kd in shapes if kd == "gemv"), None)
    if gemv_cfg is not None and gemv_shape is not None:
        rows += _demand_sweep(*gemv_shape, gemv_cfg, verbose)
    return {backend: entries}, rows


def main(verbose: bool = True, quick: bool = False):
    table, rows = tune(quick=quick, verbose=verbose)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shapes only (CI smoke)")
    ap.add_argument("--apply", action="store_true",
                    help="merge winners into the checked-in table "
                         "(src/repro/kernels/tuned_tiles.json)")
    ap.add_argument("--out", default="",
                    help="also write the table to this path")
    args = ap.parse_args()
    table, _ = tune(quick=args.quick)
    if args.out:
        print(f"wrote {dispatch.save_tuned_table(table, args.out)}")
    if args.apply:
        merged = dict(dispatch.load_tuned_table(dispatch.DEFAULT_TABLE_PATH))
        for backend, entries in table.items():
            merged.setdefault(backend, {}).update(entries)
        print(f"updated {dispatch.save_tuned_table(merged, dispatch.DEFAULT_TABLE_PATH)}")
    if not args.out and not args.apply:
        print(json.dumps(table, indent=2, sort_keys=True))

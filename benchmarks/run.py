# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark orchestrator: runs every paper-table/figure reproduction and
prints one CSV row per measurement (name,us_per_call,derived).

  PYTHONPATH=src python -m benchmarks.run [--only table3,fig9,...] [--quick]
      [--bench-out bench.jsonl] [--require-bench]

``--quick`` is the CI smoke: the kernel/dispatch/autotune/serve benches on
reduced cases, so a regression that only breaks benchmarks fails the
pipeline pre-merge (a couple of minutes, no paper-figure training loops).
``BENCH {json}`` measurement lines are captured per bench: ``--bench-out``
writes them to a jsonl file (CI uploads it as a workflow artifact), and
``--require-bench`` fails any bench that emitted none — a bench that
silently skipped all its cases looks exactly like a green run otherwise.
"""
from __future__ import annotations

import argparse
import contextlib
import io
import sys
import traceback

from benchmarks import (
    autotune,
    bench_compression,
    bench_fig10,
    bench_fig11,
    bench_fig7,
    bench_fig8,
    bench_fig9,
    bench_kernels,
    bench_serve,
    bench_table3,
)

BENCHES = {
    "table3": bench_table3.main,
    "fig7": bench_fig7.main,
    "fig8": bench_fig8.main,
    "fig9": bench_fig9.main,
    "fig10": bench_fig10.main,
    "fig11": bench_fig11.main,
    "kernels": bench_kernels.main,
    "compression": bench_compression.main,
    "serve": bench_serve.main,
    "autotune": autotune.main,
}

# benches with a reduced-case fast mode (main(verbose, quick=True))
QUICK_BENCHES = ("kernels", "autotune", "serve")


class _BenchTee(io.TextIOBase):
    """stdout tee that passes everything through and collects the
    ``BENCH {json}`` measurement lines a bench prints."""

    def __init__(self, real):
        self.real = real
        self.bench_lines: list[str] = []
        self._buf = ""

    def write(self, s: str) -> int:
        n = self.real.write(s)
        self._buf += s
        while "\n" in self._buf:
            line, self._buf = self._buf.split("\n", 1)
            if line.startswith("BENCH "):
                self.bench_lines.append(line[len("BENCH "):])
        return n

    def flush(self) -> None:
        self.real.flush()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated bench names")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: kernel/dispatch/serve benches, small cases")
    ap.add_argument("--bench-out", default="",
                    help="write every captured BENCH json line to this file")
    ap.add_argument("--require-bench", action="store_true",
                    help="fail any bench that emits no BENCH line (catches "
                         "silently-skipped cases)")
    args = ap.parse_args()
    if args.quick:
        names = [n for n in (args.only.split(",") if args.only else QUICK_BENCHES)
                 if n]
        skipped = [n for n in names if n not in QUICK_BENCHES]
        if skipped:
            print(f"--quick: skipping {skipped} (no fast mode; quick benches "
                  f"are {list(QUICK_BENCHES)})", file=sys.stderr)
        names = [n for n in names if n in QUICK_BENCHES]
        if not names:
            # running nothing must not look green (--require-bench would
            # otherwise be vacuously satisfied)
            print("--quick: no runnable benches selected", file=sys.stderr)
            sys.exit(2)
    else:
        names = [n for n in args.only.split(",") if n] or [
            n for n in BENCHES if n != "autotune"
        ]

    rows = []
    failed = []
    all_bench_lines = []
    silent = []
    for name in names:
        print(f"=== {name} ===", flush=True)
        tee = _BenchTee(sys.stdout)
        try:
            with contextlib.redirect_stdout(tee):
                fn = BENCHES[name]
                if args.quick and name in QUICK_BENCHES:
                    rows.extend(fn(verbose=True, quick=True))
                else:
                    rows.extend(fn(verbose=True))
        except Exception:  # noqa: BLE001 — report all benches even if one dies
            failed.append(name)
            traceback.print_exc()
        else:
            if not tee.bench_lines:
                silent.append(name)
        all_bench_lines.extend(tee.bench_lines)
    if args.bench_out:
        with open(args.bench_out, "w") as f:
            f.writelines(line + "\n" for line in all_bench_lines)
        print(f"wrote {len(all_bench_lines)} BENCH lines to {args.bench_out}")
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.require_bench and silent:
        print(f"NO BENCH LINES from: {silent} (bench ran green but measured "
              f"nothing — cases silently skipped?)", file=sys.stderr)
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
    if failed or (args.require_bench and silent):
        sys.exit(1)


if __name__ == "__main__":
    main()

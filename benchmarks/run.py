# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark orchestrator: runs every paper-table/figure reproduction and
prints one CSV row per measurement (name,us_per_call,derived).

  PYTHONPATH=src python -m benchmarks.run [--only table3,fig9,...] [--quick]

``--quick`` is the CI smoke: the kernel/dispatch/autotune/serve benches on
reduced cases, so a regression that only breaks benchmarks fails the
pipeline pre-merge (a couple of minutes, no paper-figure training loops).
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (
    autotune, bench_compression, bench_fig7, bench_fig8, bench_fig9,
    bench_fig10, bench_fig11, bench_kernels, bench_serve, bench_table3,
)

BENCHES = {
    "table3": bench_table3.main,
    "fig7": bench_fig7.main,
    "fig8": bench_fig8.main,
    "fig9": bench_fig9.main,
    "fig10": bench_fig10.main,
    "fig11": bench_fig11.main,
    "kernels": bench_kernels.main,
    "compression": bench_compression.main,
    "serve": bench_serve.main,
    "autotune": autotune.main,
}

# benches with a reduced-case fast mode (main(verbose, quick=True))
QUICK_BENCHES = ("kernels", "autotune", "serve")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated bench names")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: kernel/dispatch/serve benches, small cases")
    args = ap.parse_args()
    if args.quick:
        names = [n for n in (args.only.split(",") if args.only else QUICK_BENCHES)
                 if n]
        skipped = [n for n in names if n not in QUICK_BENCHES]
        if skipped:
            print(f"--quick: skipping {skipped} (no fast mode; quick benches "
                  f"are {list(QUICK_BENCHES)})", file=sys.stderr)
        names = [n for n in names if n in QUICK_BENCHES]
    else:
        names = [n for n in args.only.split(",") if n] or [
            n for n in BENCHES if n != "autotune"
        ]

    rows = []
    failed = []
    for name in names:
        print(f"=== {name} ===", flush=True)
        try:
            fn = BENCHES[name]
            if args.quick and name in QUICK_BENCHES:
                rows.extend(fn(verbose=True, quick=True))
            else:
                rows.extend(fn(verbose=True))
        except Exception:  # noqa: BLE001 — report all benches even if one dies
            failed.append(name)
            traceback.print_exc()
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()

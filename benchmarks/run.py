# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark orchestrator: runs every paper-table/figure reproduction and
prints one CSV row per measurement (name,us_per_call,derived).

  PYTHONPATH=src python -m benchmarks.run [--only table3,fig9,...]
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (
    bench_compression, bench_fig7, bench_fig8, bench_fig9, bench_fig10,
    bench_fig11, bench_kernels, bench_serve, bench_table3,
)

BENCHES = {
    "table3": bench_table3.main,
    "fig7": bench_fig7.main,
    "fig8": bench_fig8.main,
    "fig9": bench_fig9.main,
    "fig10": bench_fig10.main,
    "fig11": bench_fig11.main,
    "kernels": bench_kernels.main,
    "compression": bench_compression.main,
    "serve": bench_serve.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated bench names")
    args = ap.parse_args()
    names = [n for n in args.only.split(",") if n] or list(BENCHES)

    rows = []
    failed = []
    for name in names:
        print(f"=== {name} ===", flush=True)
        try:
            rows.extend(BENCHES[name](verbose=True))
        except Exception:  # noqa: BLE001 — report all benches even if one dies
            failed.append(name)
            traceback.print_exc()
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if failed:
        print(f"FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()

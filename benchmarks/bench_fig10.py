"""Fig. 10: design-space exploration — energy savings vs accuracy for 2-bit
(ternary, phi=1) and 3-bit (phi=4) encodings across vector lengths N.

Paper headline (ConvNet/CIFAR-10): 2-bit -> 91.95% energy eff. @ 68.47% acc;
3-bit -> 88.82% energy eff. @ 73.28% acc — i.e. 3-bit buys much more accuracy
for slightly less energy saving.
"""
from __future__ import annotations

import time

from benchmarks.common import train_cnn
from repro.core.energy import energy_savings
from repro.core.policy import QuantPolicy
from repro.core.qsq import QSQConfig
from repro.models.cnn import CONVNET4, cnn_accuracy
from repro.quant import dequantize_pytree, quantize_pytree


def main(verbose: bool = True, vector_lengths=(2, 4, 8, 16, 32, 64)):
    t0 = time.time()
    params, tr_i, tr_l, ev_i, ev_l = train_cnn(CONVNET4, steps=220, lr=1.5e-3)
    acc_fp = cnn_accuracy(params, CONVNET4, ev_i, ev_l)
    numel = 2**20  # energy model reference tensor

    rows = [("fig10/float", acc_fp, 0.0)]
    design_points = []
    for phi, be in ((1, 2), (4, 3)):
        for n in vector_lengths:
            policy = QuantPolicy(base=QSQConfig(phi=phi, group_size=n), min_numel=256)
            deq = dequantize_pytree(quantize_pytree(params, policy), like=params)
            acc = cnn_accuracy(deq, CONVNET4, ev_i, ev_l)
            es = energy_savings(numel, n, be)
            rows.append((f"fig10/be{be}_N{n}", acc, es))
            design_points.append((be, n, acc, es))
    dt = time.time() - t0
    if verbose:
        print("Fig. 10 — design space (energy savings vs accuracy):")
        for name, acc, es in rows:
            print(f"  {name:20s} acc={acc:.4f} energy_savings={es * 100:.2f}%")
        # the paper's qualitative claim: at matched N, 2-bit saves slightly
        # more energy but loses much more accuracy
        for n in vector_lengths:
            p2 = next(p for p in design_points if p[0] == 2 and p[1] == n)
            p3 = next(p for p in design_points if p[0] == 3 and p[1] == n)
            print(f"  N={n:3d}: 2b acc={p2[2]:.3f}/es={p2[3]:.3f} | "
                  f"3b acc={p3[2]:.3f}/es={p3[3]:.3f} | "
                  f"claim(2b es>3b es)={p2[3] > p3[3]} claim(3b acc>=2b acc)={p3[2] >= p2[2]}")
    return [(name, dt / len(rows) * 1e6, f"acc={acc:.4f}|es={es:.4f}")
            for name, acc, es in rows]


if __name__ == "__main__":
    main()

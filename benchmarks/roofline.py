"""Aggregate experiments/dryrun/*.json into the §Roofline table.

Usage:  PYTHONPATH=src python -m benchmarks.roofline [--markdown]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_cells(mesh: str = "16x16", tag: str | None = None):
    cells = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("mesh") != mesh:
            continue
        parts = p.stem.split("__")
        cell_tag = parts[3] if len(parts) > 3 else None
        if cell_tag != tag:
            continue
        cells.append(d)
    return cells


def table(cells, markdown: bool = True) -> str:
    hdr = ("arch", "shape", "compute_s", "memory_s", "collective_s",
           "dominant", "frac", "useful", "peak_GB")
    lines = []
    if markdown:
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    for d in cells:
        if not d.get("supported", False):
            row = (d["arch"], d["shape"], "-", "-", "-",
                   f"SKIP: {d.get('skip_reason', '')[:40]}", "-", "-", "-")
        else:
            rt = d["roofline"]
            peak = (d["per_device"].get("peak_bytes") or 0) / 1e9
            row = (d["arch"], d["shape"], f"{rt['compute_s']:.3e}",
                   f"{rt['memory_s']:.3e}", f"{rt['collective_s']:.3e}",
                   rt["dominant"], f"{rt['roofline_fraction']:.3f}",
                   f"{d['useful_flops_ratio']:.2f}", f"{peak:.1f}")
        lines.append(("| " + " | ".join(row) + " |") if markdown
                     else ",".join(row))
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default=None)
    ap.add_argument("--csv", action="store_true")
    args = ap.parse_args()
    cells = load_cells(args.mesh, args.tag)
    print(table(cells, markdown=not args.csv))
    print(f"\n{len(cells)} cells on mesh {args.mesh}"
          + (f" tag={args.tag}" if args.tag else ""))


if __name__ == "__main__":
    main()

"""QSQ gradient compression (beyond-paper, DESIGN.md §7.1): wire bytes
crossing the (simulated) cross-pod channel vs convergence, with and without
error feedback — the training-time counterpart of the paper's Fig. 10
energy/quality trade-off.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.data.pipeline import LMDataConfig, lm_batch
from repro.models.api import Model
from repro.models.base import init_params
from repro.optim import AdamWConfig, GradCompressionConfig
from repro.train.state import train_state_descs
from repro.train.step import make_train_step

STEPS = 40


def _run(cc: GradCompressionConfig):
    cfg = get_arch("smollm_135m", smoke=True)
    model = Model(cfg)
    data = LMDataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    step = jax.jit(make_train_step(model, AdamWConfig(lr=3e-3), cc, STEPS),
                   donate_argnums=(0,))
    state = init_params(jax.random.PRNGKey(0), train_state_descs(model, cc))
    losses, wire = [], 0.0
    for s in range(STEPS):
        state, m = step(state, lm_batch(data, s))
        losses.append(float(m["loss"]))
        wire += float(m["grad_wire_bytes"])
    return losses, wire


def main(verbose: bool = True):
    t0 = time.time()
    base_losses, _ = _run(GradCompressionConfig(enabled=False))
    comp_losses, wire = _run(GradCompressionConfig(enabled=True, min_numel=64))

    # raw f32 grad bytes that WOULD cross the channel per step
    cfg = get_arch("smollm_135m", smoke=True)
    model = Model(cfg)
    params = init_params(jax.random.PRNGKey(0), model.param_descs())
    raw_per_step = sum(a.size * 4 for a in jax.tree_util.tree_leaves(params)
                       if a.ndim >= 2 and a.size >= 64)
    ratio = raw_per_step * STEPS / max(wire, 1.0)

    final_gap = np.mean(comp_losses[-5:]) - np.mean(base_losses[-5:])
    dt = time.time() - t0
    rows = [
        ("compression/final_loss_uncompressed", np.mean(base_losses[-5:])),
        ("compression/final_loss_qsq_ef", np.mean(comp_losses[-5:])),
        ("compression/loss_gap", final_gap),
        ("compression/wire_reduction_x", ratio),
    ]
    if verbose:
        print("QSQ gradient compression (error feedback), 40 steps:")
        for name, v in rows:
            print(f"  {name:40s} {v:.4f}")
        print(f"  grads cross the channel {ratio:.2f}x smaller; "
              f"loss gap {final_gap:+.4f}")
    return [(name, dt / len(rows) * 1e6, f"{v:.4f}") for name, v in rows]


if __name__ == "__main__":
    main()

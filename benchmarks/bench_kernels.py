"""Kernel microbenchmarks: fused QSQ dequant-matmul vs dense matmul.

On this CPU container the Pallas kernel runs in interpret mode (correctness
only — interpret timing is meaningless), so the WALL numbers compare the
jitted XLA reference paths; the DERIVED numbers are the structural win on the
target TPU: HBM bytes for weight streaming (the paper's energy/bandwidth
claim, Eq. 11/12, restated as the decode-shape memory-roofline term).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import timeit_us
from repro.core import codec
from repro.core.energy import TPU_HBM_BW
from repro.kernels import ops, ref

CASES = [
    # (M, K, N, G) — decode-ish GEMMs (small M = batch, big K/N = weights)
    (8, 2048, 2048, 64),
    (8, 4096, 4096, 64),
    (128, 4096, 4096, 64),
]


def main(verbose: bool = True):
    rows = []
    for m, k, n, g in CASES:
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (k, n), jnp.float32) * 0.05
        x = jax.random.normal(key, (m, k), jnp.bfloat16)
        planes, scales = ops.pack_weight(w, group_size=g, use_pallas=False)
        wq = ref.qsq_dequant_ref(planes, scales, g).astype(jnp.bfloat16)

        dense_us = timeit_us(jax.jit(lambda x, w: x @ w), x, wq)
        fused_us = timeit_us(
            jax.jit(lambda x, p, s: ref.qsq_matmul_ref(x, p, s, g)), x, planes, scales
        )

        wbytes_dense = k * n * 2  # bf16
        wbytes_packed = planes.size * 4 + scales.size * 4
        ratio = wbytes_dense / wbytes_packed
        # decode-shape memory-roofline term for weight streaming (per layer)
        t_dense = wbytes_dense / TPU_HBM_BW * 1e6
        t_packed = wbytes_packed / TPU_HBM_BW * 1e6

        name = f"kernels/qsq_matmul_{m}x{k}x{n}"
        rows.append((name, fused_us,
                     f"dense_us={dense_us:.0f}|hbm_ratio={ratio:.2f}x"
                     f"|tpu_wstream_us={t_packed:.1f}_vs_{t_dense:.1f}"))
        if verbose:
            print(f"  {name}: xla_fused={fused_us:.0f}us dense={dense_us:.0f}us "
                  f"| weight bytes {wbytes_packed / 1e6:.2f}MB vs "
                  f"{wbytes_dense / 1e6:.2f}MB ({ratio:.2f}x) "
                  f"| TPU weight-stream {t_packed:.1f}us vs {t_dense:.1f}us")

    # encode throughput (grad compression / checkpoint writer path)
    k, n, g = 4096, 4096, 64
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.05
    enc_us = timeit_us(
        jax.jit(lambda w: ref.qsq_quantize_ref(w, g, 4)), w
    )
    rows.append(("kernels/qsq_quantize_4096x4096", enc_us,
                 f"GBps={(k * n * 4) / (enc_us / 1e6) / 1e9:.2f}"))
    if verbose:
        print(f"  encode 4096x4096: {enc_us:.0f}us")
    return rows


if __name__ == "__main__":
    main()

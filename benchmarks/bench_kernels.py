"""Kernel microbenchmarks: fused QSQ dequant-matmul vs dense matmul.

On this CPU container the Pallas kernels run in interpret mode (correctness
only — interpret timing is meaningless), so the WALL numbers compare the
jitted XLA reference paths; the DERIVED numbers are the structural win on the
target TPU: HBM bytes for weight streaming (the paper's energy/bandwidth
claim, Eq. 11/12, restated as the decode-shape memory-roofline term).

Each case emits one ``BENCH {json}`` line (bench=kernels) carrying the
wall times, the HBM ratio, and the route + tiles `kernels/dispatch.py`
picked for the shape — including the decode-shape GEMV cases and a
tile-ragged case that exercises the padded dispatch — so the perf
trajectory captures kernel-level numbers alongside ``bench_serve``.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp

from benchmarks.common import timeit_us
from repro.core.energy import TPU_HBM_BW
from repro.kernels import dispatch, ops, ref

CASES = [
    # (M, K, N, G) — decode GEMVs (M = batch slots x 1 token)
    (1, 4096, 4096, 64),
    (8, 2048, 2048, 64),
    (8, 4096, 4096, 64),
    # prefill/train GEMMs
    (128, 4096, 4096, 64),
    # tile-ragged decode shape: goes through padded GEMV dispatch
    (8, 2080, 300, 16),
]
QUICK_CASES = [(8, 512, 512, 64), (64, 512, 512, 64), (8, 2080, 300, 16)]


def main(verbose: bool = True, quick: bool = False):
    rows = []
    for m, k, n, g in (QUICK_CASES if quick else CASES):
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (k, n), jnp.float32) * 0.05
        x = jax.random.normal(key, (m, k), jnp.bfloat16)
        planes, scales = ops.pack_weight(w, group_size=g, use_pallas=False)
        wq = ref.qsq_dequant_ref(planes, scales, g).astype(jnp.bfloat16)

        dense_us = timeit_us(jax.jit(lambda x, w: x @ w), x, wq)
        # On TPU, time the actually-dispatched kernel (routed, padded);
        # interpret-mode kernel timing is meaningless, so CPU times the
        # jitted XLA packed reference instead — the BENCH line says which.
        on_tpu = jax.default_backend() == "tpu"
        if on_tpu:
            fused_us = timeit_us(
                jax.jit(lambda x, p, s: dispatch.packed_matmul(
                    x, p, s, group_size=g)), x, planes, scales
            )
        else:
            fused_us = timeit_us(
                jax.jit(lambda x, p, s: ref.qsq_matmul_ref(x, p, s, g)),
                x, planes, scales
            )
        plan = dispatch.plan(m, k, n, g)

        wbytes_dense = k * n * 2  # bf16
        wbytes_packed = planes.size * 4 + scales.size * 4
        ratio = wbytes_dense / wbytes_packed
        # decode-shape memory-roofline term for weight streaming (per layer)
        t_dense = wbytes_dense / TPU_HBM_BW * 1e6
        t_packed = wbytes_packed / TPU_HBM_BW * 1e6

        case = dispatch.shape_key(m, k, n, g)
        name = f"kernels/qsq_matmul_{m}x{k}x{n}"
        rows.append((name, fused_us,
                     f"dense_us={dense_us:.0f}|hbm_ratio={ratio:.2f}x"
                     f"|tpu_wstream_us={t_packed:.1f}_vs_{t_dense:.1f}"
                     f"|route={plan.route}"))
        print("BENCH " + json.dumps({
            "bench": "kernels",
            "case": case,
            "route": plan.route,
            "tiles": [plan.bm, plan.bk, plan.bn],
            "padded": plan.padded,
            "timed": "dispatch" if on_tpu else "xla_ref",
            "fused_us": round(fused_us, 1),
            "dense_us": round(dense_us, 1),
            "hbm_ratio": round(ratio, 2),
            "tpu_wstream_us": round(t_packed, 1),
            "tpu_wstream_dense_us": round(t_dense, 1),
        }))
        if verbose:
            fl = "dispatch" if on_tpu else "xla_fused"
            print(f"  {name}: {fl}={fused_us:.0f}us dense={dense_us:.0f}us "
                  f"| weight bytes {wbytes_packed / 1e6:.2f}MB vs "
                  f"{wbytes_dense / 1e6:.2f}MB ({ratio:.2f}x) "
                  f"| TPU weight-stream {t_packed:.1f}us vs {t_dense:.1f}us "
                  f"| route {plan.route} tiles {plan.bm}x{plan.bk}x{plan.bn}"
                  f"{' (padded)' if plan.padded else ''}")

    # encode throughput (grad compression / checkpoint writer path)
    k, n, g = (512, 512, 64) if quick else (4096, 4096, 64)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.05
    enc_us = timeit_us(
        jax.jit(lambda w: ref.qsq_quantize_ref(w, g, 4)), w
    )
    gbps = (k * n * 4) / (enc_us / 1e6) / 1e9
    rows.append((f"kernels/qsq_quantize_{k}x{n}", enc_us, f"GBps={gbps:.2f}"))
    print("BENCH " + json.dumps({
        "bench": "kernels", "case": f"quantize_{k}x{n}",
        "us": round(enc_us, 1), "GBps": round(gbps, 2),
    }))
    if verbose:
        print(f"  encode {k}x{n}: {enc_us:.0f}us")
    return rows


if __name__ == "__main__":
    main()

"""Shared helpers for the paper-reproduction benchmarks."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.data.pipeline import image_batches, synthetic_image_dataset
from repro.models.base import init_params
from repro.models.cnn import CNNConfig, cnn_descs, cnn_loss
from repro.optim import AdamWConfig, adamw_init_descs, adamw_update


_TRAIN_CACHE: dict = {}


def train_cnn(cfg: CNNConfig, steps: int = 150, lr: float = 2e-3,
              n: int = 768, seed: int = 0, batch: int = 64,
              noise: float = 0.30):
    """Train a CNN on the synthetic class-template dataset (cached per
    config so the fig7/fig8/fig10 benches reuse one trained model).  Returns
    (params, train_images, train_labels, eval_images, eval_labels)."""
    key = (cfg.name, steps, lr, n, seed, batch, noise)
    if key in _TRAIN_CACHE:
        return _TRAIN_CACHE[key]
    imgs, labels = synthetic_image_dataset(
        n, cfg.input_hw, cfg.input_c, cfg.n_classes, seed=seed, noise=noise
    )
    n_eval = max(n // 4, 64)
    tr_i, tr_l = imgs[:-n_eval], labels[:-n_eval]
    ev_i, ev_l = imgs[-n_eval:], labels[-n_eval:]

    params = init_params(jax.random.PRNGKey(seed), cnn_descs(cfg))
    opt = init_params(jax.random.PRNGKey(seed), adamw_init_descs(cnn_descs(cfg)))
    ocfg = AdamWConfig(lr=lr, weight_decay=0.0)

    @jax.jit
    def step(params, opt, b):
        loss, grads = jax.value_and_grad(lambda p: cnn_loss(p, cfg, b))(params)
        params, opt, _ = adamw_update(ocfg, params, grads, opt)
        return params, opt, loss

    it = image_batches(tr_i, tr_l, batch, seed=seed + 1)
    for _ in range(steps):
        _, b = next(it)
        params, opt, _ = step(params, opt, b)
    out = (params, tr_i, tr_l, ev_i, ev_l)
    _TRAIN_CACHE[key] = out
    return out


def finetune_fc(params, cfg: CNNConfig, imgs, labels, steps: int = 60,
                lr: float = 1e-3, seed: int = 3):
    """FC-only fine-tune (convs frozen) — Table III rows 3/4."""
    ocfg = AdamWConfig(lr=lr, weight_decay=0.0)
    opt = init_params(jax.random.PRNGKey(seed), adamw_init_descs(cnn_descs(cfg)))

    @jax.jit
    def step(params, opt, b):
        loss, grads = jax.value_and_grad(lambda p: cnn_loss(p, cfg, b))(params)
        grads = {"convs": jax.tree_util.tree_map(jnp.zeros_like, grads["convs"]),
                 "fcs": grads["fcs"]}
        params, opt, _ = adamw_update(ocfg, params, grads, opt)
        return params, opt, loss

    it = image_batches(imgs, labels, 64, seed=seed)
    for _ in range(steps):
        _, b = next(it)
        params, opt, _ = step(params, opt, b)
    return params


def timeit_us(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6

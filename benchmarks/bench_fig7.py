"""Fig. 7: accuracy scales with quantization level phi (LeNet).

Paper: phi in {1, 2, 4} <-> levels {+-1}, {+-1,+-2}, {+-1,+-2,+-4};
accuracy increases monotonically with phi.
"""
from __future__ import annotations

import time

from benchmarks.common import train_cnn
from repro.core.policy import QuantPolicy
from repro.core.qsq import QSQConfig
from repro.models.cnn import LENET, cnn_accuracy
from repro.quant import dequantize_pytree, quantize_pytree


def main(verbose: bool = True):
    t0 = time.time()
    params, tr_i, tr_l, ev_i, ev_l = train_cnn(LENET, steps=400, n=1024)
    acc_fp = cnn_accuracy(params, LENET, ev_i, ev_l)
    rows = [("fig7/float", acc_fp)]
    for phi in (1, 2, 4):
        policy = QuantPolicy(base=QSQConfig(phi=phi, group_size=16), min_numel=256)
        deq = dequantize_pytree(quantize_pytree(params, policy), like=params)
        rows.append((f"fig7/phi{phi}", cnn_accuracy(deq, LENET, ev_i, ev_l)))
    for phi in (1, 2, 4):
        policy = QuantPolicy(
            base=QSQConfig(phi=phi, group_size=16, refit_alpha=True), min_numel=256
        )
        deq = dequantize_pytree(quantize_pytree(params, policy), like=params)
        rows.append((f"fig7/phi{phi}_refit", cnn_accuracy(deq, LENET, ev_i, ev_l)))
    dt = time.time() - t0
    if verbose:
        print("Fig. 7 — accuracy vs quantization level:")
        for name, acc in rows:
            print(f"  {name:16s} acc={acc:.4f}")
        accs = [a for n, a in rows if n.endswith("_refit")]
        print(f"  refit monotone non-decreasing with phi: {accs == sorted(accs)}")
    return [(name, dt / len(rows) * 1e6, f"{acc:.4f}") for name, acc in rows]


if __name__ == "__main__":
    main()

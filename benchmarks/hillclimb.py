"""§Perf hillclimb driver: re-runs selected cells with a named change and
prints before/after roofline terms.

  PYTHONPATH=src python -m benchmarks.hillclimb --cell qwen3_moe_30b_a3b:train_4k --change moe_local
  PYTHONPATH=src python -m benchmarks.hillclimb --cell deepseek_7b:decode_32k --change packed

Changes:
  moe_local   — shard-local MoE capacity routing (models/layers.py::moe);
                the baseline JSONs were recorded with global routing, so a
                plain re-run measures the change.
  packed      — QSQ bit-plane weights for decode/prefill (quant/packed.py).
  cache_batch — decode KV cache sharded on batch+kv_heads instead of seq
                (avoids the involuntary full remat on cache update).
  no_fsdp     — replicate params over the data axis (kills the per-layer
                weight all-gather at the cost of memory).
  seq_model   — (default baseline cache sharding) no-op re-run.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json  # noqa: E402

from repro.launch.dryrun import run_cell  # noqa: E402

CHANGES = {
    "moe_local": dict(),
    "packed": dict(packed=True),
    "cache_batch": dict(rules_override={"seq_kv": ()}),
    "packed_cache_batch": dict(packed=True, rules_override={"seq_kv": ()}),
    "no_fsdp": dict(fsdp=False),
    "context_parallel": dict(rules_override={
        "seq_act": ("model",), "heads": (), "kv_heads": (), "mlp": (),
        "vocab": (), "embed": (),
    }),
    "baseline_rerun": dict(),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape")
    ap.add_argument("--change", required=True, choices=list(CHANGES))
    args = ap.parse_args()
    arch, shape = args.cell.split(":")

    r = run_cell(arch, shape, tag=args.change, **CHANGES[args.change])
    rt = r["roofline"]
    print(json.dumps({
        "cell": args.cell, "change": args.change,
        "compute_s": rt["compute_s"], "memory_s": rt["memory_s"],
        "collective_s": rt["collective_s"], "dominant": rt["dominant"],
        "roofline_fraction": rt["roofline_fraction"],
        "useful": r["useful_flops_ratio"],
        "peak_GB": (r["per_device"].get("peak_bytes") or 0) / 1e9,
        "arg_GB": (r["per_device"].get("argument_bytes") or 0) / 1e9,
    }, indent=1))


if __name__ == "__main__":
    main()

"""Table III: LeNet accuracy — float / quantized / FC-finetuned.

Paper numbers (MNIST): 98.68% float, 97.59% quantized no-retrain,
98.35% after 5-epoch FC fine-tune, 98.55% after 20 epochs.
Ours run on the synthetic image dataset (no MNIST offline) — the DELTAS are
the reproduced quantity.
"""
from __future__ import annotations

import time

from benchmarks.common import finetune_fc, train_cnn
from repro.core.policy import QuantPolicy
from repro.core.qsq import QSQConfig
from repro.models.cnn import LENET, cnn_accuracy
from repro.quant import dequantize_pytree, quantize_pytree

PAPER = {"float": 0.9868, "quantized": 0.9759, "ft_short": 0.9835, "ft_long": 0.9855}


def main(verbose: bool = True):
    t0 = time.time()
    params, tr_i, tr_l, ev_i, ev_l = train_cnn(LENET, steps=400, n=1024)
    acc_fp = cnn_accuracy(params, LENET, ev_i, ev_l)

    policy = QuantPolicy(base=QSQConfig(phi=4, group_size=16), min_numel=256)
    deq = dequantize_pytree(quantize_pytree(params, policy), like=params)
    acc_q = cnn_accuracy(deq, LENET, ev_i, ev_l)

    ft_short = finetune_fc(deq, LENET, tr_i, tr_l, steps=30)
    acc_fts = cnn_accuracy(ft_short, LENET, ev_i, ev_l)
    ft_long = finetune_fc(deq, LENET, tr_i, tr_l, steps=120)
    acc_ftl = cnn_accuracy(ft_long, LENET, ev_i, ev_l)

    # beyond-paper: least-squares alpha refit (same 3-bit wire format)

    rpolicy = QuantPolicy(
        base=QSQConfig(phi=4, group_size=16, refit_alpha=True), min_numel=256
    )
    deq_r = dequantize_pytree(quantize_pytree(params, rpolicy), like=params)
    acc_refit = cnn_accuracy(deq_r, LENET, ev_i, ev_l)

    dt = time.time() - t0
    rows = [
        ("table3/float", acc_fp, PAPER["float"]),
        ("table3/quantized_no_retrain", acc_q, PAPER["quantized"]),
        ("table3/fc_finetune_short", acc_fts, PAPER["ft_short"]),
        ("table3/fc_finetune_long", acc_ftl, PAPER["ft_long"]),
        ("table3/quantized_refit_alpha(ours)", acc_refit, PAPER["quantized"]),
    ]
    if verbose:
        print("Table III (ours vs paper):")
        for name, ours, paper in rows:
            print(f"  {name:32s} ours={ours:.4f} paper={paper:.4f}")
        print(f"  drop ours={acc_fp-acc_q:+.4f} paper={PAPER['float']-PAPER['quantized']:+.4f}")
    return [(name, dt / 5 * 1e6, f"{ours:.4f}|paper={paper:.4f}")
            for name, ours, paper in rows]


if __name__ == "__main__":
    main()

"""Fig. 8: per-layer quantization sensitivity of the 4-layer ConvNet for
varying vector lengths N (the paper quantizes the 1st/2nd/3rd/4th conv layer
one at a time and reports accuracy)."""
from __future__ import annotations

import time

from benchmarks.common import train_cnn
from repro.core.policy import QuantPolicy
from repro.core.qsq import QSQConfig
from repro.models.cnn import CONVNET4, cnn_accuracy
from repro.quant import dequantize_pytree, quantize_pytree


def main(verbose: bool = True, vector_lengths=(4, 16, 64)):
    t0 = time.time()
    params, tr_i, tr_l, ev_i, ev_l = train_cnn(CONVNET4, steps=220, lr=1.5e-3)
    acc_fp = cnn_accuracy(params, CONVNET4, ev_i, ev_l)
    rows = [("fig8/float", acc_fp, "")]

    for n in vector_lengths:
        for layer in range(4):
            # quantize ONLY conv layer `layer`: exclude everything else
            policy = QuantPolicy(
                base=QSQConfig(phi=4, group_size=n),
                min_numel=1,
                min_ndim=2,
                exclude_res=tuple(
                    [rf"convs/{i}/" for i in range(4) if i != layer] + ["fcs/"]
                ),
            )
            deq = dequantize_pytree(quantize_pytree(params, policy), like=params)
            acc = cnn_accuracy(deq, CONVNET4, ev_i, ev_l)
            rows.append((f"fig8/N{n}_conv{layer + 1}", acc, f"drop={acc_fp - acc:+.4f}"))
    dt = time.time() - t0
    if verbose:
        print("Fig. 8 — ConvNet per-layer quantization (accuracy):")
        for name, acc, extra in rows:
            print(f"  {name:22s} acc={acc:.4f} {extra}")
    return [(name, dt / len(rows) * 1e6, f"{acc:.4f}{('|' + e) if e else ''}")
            for name, acc, e in rows]


if __name__ == "__main__":
    main()
